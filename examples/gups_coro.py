"""GUPS with the coroutine pipeline — the paper's flagship benchmark as a
TPU kernel (interpret mode on CPU), plus the calibrated model's predicted
speedups at disaggregated-memory latencies.

  PYTHONPATH=src python examples/gups_coro.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import autotune, sim
from repro.core.descriptors import plan_gather
from repro.core.machine import get_machine
from repro.core.schedule import TileProfile, achieved_bandwidth, solve_depth
from repro.kernels.coro_gather.coro_gather import row_gather_spec
from repro.kernels.coro_gather.ops import coro_gather
from repro.kernels.coro_scatter_add.coro_scatter_add import scatter_add_spec
from repro.kernels.coro_scatter_add.ops import coro_scatter_add


def main():
    m = get_machine()
    print(f"machine profile: {m.name} "
          f"(hbm latency {m.hbm_latency_s * 1e9:.0f}ns, "
          f"{m.hbm_bw / 1e9:.0f} GB/s, {m.request_slots} request slots; "
          f"switch with REPRO_MACHINE=v5e-far-800ns)")

    rng = np.random.RandomState(0)
    table = jnp.asarray(rng.randn(1024, 128), jnp.float32)
    idx = rng.randint(0, 1024, 256).astype(np.int32)
    upd = jnp.asarray(rng.randn(256, 128) * 0.1, jnp.float32)

    # GUPS = random gather + scatter-update, both through decoupled DMA;
    # both kernels are CoroSpec declarations — scratch, semaphores and the
    # schedule are derived, and depth=None solves from the classified context
    gathered = coro_gather(table, jnp.asarray(idx))
    updated = coro_scatter_add(table, idx, upd)
    print(f"gather ok: {gathered.shape}; update ok: {updated.shape} "
          f"(dedup handled {256 - len(np.unique(idx))} duplicate rows)")

    for spec, key in ((row_gather_spec(8, 128, jnp.float32), "row_gather"),
                      (scatter_add_spec(8, 128, jnp.float32), "scatter_add")):
        depth = autotune.last_choice(key)
        print(f"{key}: chose depth {depth}; derived context "
              f"{spec.context_bytes(depth)} B "
              f"(all-private baseline {spec.context_bytes(depth, baseline=True)} B)")

    plan = plan_gather(idx, span=8)
    print(f"coalescing on random indices: {plan.n_requests} -> "
          f"{plan.requests_issued()} requests (random barely coalesces, "
          "as the paper observes for GUPS)")

    # latency-aware depth: the dynamic-scheduler analogue (DESIGN.md 2.1)
    p = TileProfile(tile_bytes=8 * 128 * 4, flops_per_tile=8 * 128.0)
    for lat_ns in (200, 800):
        d = solve_depth(p, latency_s=lat_ns * 1e-9)
        bw = achieved_bandwidth(p, d, latency_s=lat_ns * 1e-9) / 1e9
        bw2 = achieved_bandwidth(p, 2, latency_s=lat_ns * 1e-9) / 1e9
        print(f"{lat_ns}ns: depth {d} sustains {bw:.0f} GB/s "
              f"(double-buffer only: {bw2:.0f} GB/s)")

    # the paper's reported result, from the calibrated model
    g = sim.BENCHES["GUPS"]
    for lat in (200, 800):
        s = sim.speedup("coroamu-full", g, latency_ns=lat)
        print(f"CoroAMU-Full GUPS @{lat}ns: {s:.1f}x over serial "
              f"(paper: {'29.0' if lat == 200 else '59.8'}x)")

    # every launched pipeline above fed the always-on transfer telemetry
    print("telemetry:", autotune.telemetry_summary())


if __name__ == "__main__":
    main()

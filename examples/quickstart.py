"""Quickstart: train a small LM on the synthetic Markov task, checkpoint,
resume, and serve a few tokens — the whole public API in one script.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config
from repro.core.autotune import telemetry_summary
from repro.core.machine import get_machine
from repro.data.pipeline import DataConfig, MarkovTask
from repro.launch.serve import serve
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime.train_loop import train


def main():
    # 0) the active machine model every depth solve / roofline term reads
    print(f"machine profile: {get_machine().name} "
          f"(REPRO_MACHINE selects; see repro.core.machine)")

    # 1) pick an assigned architecture at smoke scale
    cfg = get_config("granite-3-2b").reduced().replace(vocab=128)
    model = build_model(cfg)
    print(f"arch={cfg.name}  params={model.n_params()/1e6:.2f}M")

    # 2) train on the seeded Markov task (loss floor = chain entropy)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, branching=2)
    task_floor = MarkovTask(data).entropy()
    with tempfile.TemporaryDirectory() as ckpt:
        rep = train(model, steps=60, data_cfg=data,
                    opt=AdamWConfig(lr=5e-3, total_steps=60, warmup_steps=5),
                    ckpt_dir=ckpt, ckpt_every=30)
        first, last = min(rep.losses), max(rep.losses)
        print(f"loss: {rep.losses[first]:.3f} -> {rep.losses[last]:.3f} "
              f"(floor ~{task_floor:.3f})")

        # 3) resume exactly from the checkpoint (fault-tolerant restart path)
        rep2 = train(build_model(cfg), steps=61, data_cfg=data,
                     opt=AdamWConfig(lr=5e-3, total_steps=61, warmup_steps=5),
                     ckpt_dir=ckpt)
        print(f"resumed from step {rep2.resumed_from}")

    # 4) serve: batched prefill + decode with KV caches
    stats = serve(cfg, batch=2, prompt_len=16, gen=6)
    print("serve:", stats)

    # 5) the decode loop fed the always-on transfer telemetry as it ran
    print("telemetry:", telemetry_summary())


if __name__ == "__main__":
    main()

"""Batched serving: prefill a batch of prompts, then decode with KV caches —
including a sliding-window (hymba) and an SSM (mamba2) arch to show the three
cache families (full flash-decode / ring / recurrent state).

For each attention arch we also report what the TPU flash-decode kernel
would run with at that arch's full cache shape: the `CoroSpec`-derived
context bytes (k/v slots x depth + shared online-softmax accumulators) and
the latency-aware depth `core.autotune` solves from it.

`--engine paged` instead drives the continuous-batching engine
(repro.serve): ragged prompts through a block pool deliberately smaller
than the workload's aggregate KV, so completions must free pages for later
admissions — the paged analogue of the coroutine pipeline reusing slots.

  PYTHONPATH=src python examples/serve_batched.py [--engine dense|paged|both]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import autotune
from repro.kernels.decode_attention.decode_attention import decode_spec
from repro.launch.serve import serve


def dense_demo():
    for arch in ("yi-6b", "hymba-1.5b", "mamba2-130m"):
        cfg = get_config(arch).reduced()
        stats = serve(cfg, batch=4, prompt_len=48, gen=12)
        print(f"{arch:15s} {stats}")
        if cfg.n_heads and cfg.kv_heads:
            d = cfg.resolved_head_dim
            g = max(cfg.n_heads // cfg.kv_heads, 1)
            spec = decode_spec(128, cfg.kv_heads, g, d, jnp.bfloat16)
            depth = autotune.choose_depth(spec.profile(), vars=spec.all_vars())
            print(f"{'':15s} flash-decode spec: depth {depth}, context "
                  f"{spec.context_bytes(depth)} B (all-private baseline "
                  f"{spec.context_bytes(depth, baseline=True)} B)")


def paged_demo():
    """Serve 8 ragged requests through a pool that holds ~2 of them: the
    aggregate KV footprint exceeds the pool (and any dense [batch, max_len]
    carve-up of the same memory) by >2x, yet every request completes."""
    from repro.serve import PagedServingEngine

    cfg = get_config("yi-6b").reduced()
    rng = np.random.default_rng(0)
    block_size, gen = 8, 10
    plens = [10, 40, 12, 36, 9, 28, 14, 33]
    blocks_per_req = -(-(max(plens) + gen) // block_size)
    eng = PagedServingEngine(cfg, block_size=block_size,
                             num_blocks=2 * blocks_per_req, max_in_flight=3)
    for plen in plens:
        eng.submit(rng.integers(0, cfg.vocab, plen), max_new_tokens=gen)
    stats = eng.run()
    keys = ("requests", "completed", "rounds", "preemptions", "round_width",
            "solved_depth", "pool_tokens", "aggregate_kv_tokens",
            "kv_oversubscription", "decode_tok_per_s", "p50_ms", "p99_ms")
    print(f"{'paged yi-6b':15s} " + " ".join(f"{k}={stats[k]}" for k in keys))
    assert stats["completed"] == len(plens), stats
    assert stats["kv_oversubscription"] >= 2.0, stats
    return stats


def prefix_demo():
    """8 requests opening with one shared system prompt, cache on vs off:
    the warm run reuses the prefix pages (prefix_hits > 0) and pops strictly
    fewer physical blocks off the pool, emitting identical tokens."""
    from repro.serve import PagedServingEngine

    cfg = get_config("yi-6b").reduced()
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    block_size, gen = 8, 6
    system = list(rng.integers(0, cfg.vocab, 3 * block_size))
    prompts = [system + list(rng.integers(0, cfg.vocab, 5 + i % 6))
               for i in range(8)]

    def run(prefix_cache):
        eng = PagedServingEngine(cfg, block_size=block_size, num_blocks=64,
                                 params=params, max_in_flight=2,
                                 prefix_cache=prefix_cache)
        rids = [eng.submit(p, max_new_tokens=gen) for p in prompts]
        stats = eng.run()
        return [eng.request(r).generated for r in rids], stats

    warm_toks, warm = run(True)
    cold_toks, cold = run(False)
    keys = ("prefix_hits", "prefix_tokens", "blocks_shared",
            "blocks_allocated", "cow_forks", "cache_blocks", "ttft_p50_ms")
    print(f"{'prefix yi-6b':15s} warm: "
          + " ".join(f"{k}={warm[k]}" for k in keys))
    print(f"{'':15s} cold: blocks_allocated={cold['blocks_allocated']} "
          f"prefix_hits={cold['prefix_hits']}")
    assert warm_toks == cold_toks, "prefix cache changed emitted tokens"
    assert warm["prefix_hits"] > 0, warm
    assert warm["blocks_allocated"] < cold["blocks_allocated"], (warm, cold)
    return warm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="both",
                    choices=["dense", "paged", "both"])
    ap.add_argument("--prefix-cache", action="store_true",
                    help="run the shared-prefix dedup demo (paged engine)")
    args = ap.parse_args(argv)
    if args.engine in ("dense", "both"):
        dense_demo()
    if args.engine in ("paged", "both"):
        paged_demo()
        if args.prefix_cache or args.engine == "paged":
            prefix_demo()


if __name__ == "__main__":
    main()

"""Batched serving: prefill a batch of prompts, then decode with KV caches —
including a sliding-window (hymba) and an SSM (mamba2) arch to show the three
cache families (full flash-decode / ring / recurrent state).

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config
from repro.launch.serve import serve


def main():
    for arch in ("yi-6b", "hymba-1.5b", "mamba2-130m"):
        cfg = get_config(arch).reduced()
        stats = serve(cfg, batch=4, prompt_len=48, gen=12)
        print(f"{arch:15s} {stats}")


if __name__ == "__main__":
    main()

"""Batched serving: prefill a batch of prompts, then decode with KV caches —
including a sliding-window (hymba) and an SSM (mamba2) arch to show the three
cache families (full flash-decode / ring / recurrent state).

For each attention arch we also report what the TPU flash-decode kernel
would run with at that arch's full cache shape: the `CoroSpec`-derived
context bytes (k/v slots x depth + shared online-softmax accumulators) and
the latency-aware depth `core.autotune` solves from it.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp

from repro.configs import get_config
from repro.core import autotune
from repro.kernels.decode_attention.decode_attention import decode_spec
from repro.launch.serve import serve


def main():
    for arch in ("yi-6b", "hymba-1.5b", "mamba2-130m"):
        cfg = get_config(arch).reduced()
        stats = serve(cfg, batch=4, prompt_len=48, gen=12)
        print(f"{arch:15s} {stats}")
        if cfg.n_heads and cfg.kv_heads:
            d = cfg.resolved_head_dim
            g = max(cfg.n_heads // cfg.kv_heads, 1)
            spec = decode_spec(128, cfg.kv_heads, g, d, jnp.bfloat16)
            depth = autotune.choose_depth(spec.profile(), vars=spec.all_vars())
            print(f"{'':15s} flash-decode spec: depth {depth}, context "
                  f"{spec.context_bytes(depth)} B (all-private baseline "
                  f"{spec.context_bytes(depth, baseline=True)} B)")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-parameter granite-family model for a few
hundred steps on the synthetic Markov corpus, with checkpointing, straggler
monitoring, and exact resume.

At full scale the same code path runs under the production mesh
(launch/train.py --mesh; sharding comes from the logical-axis rules). On this
CPU container the default dims give ~100M params; pass --steps to shorten.

  PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config
from repro.data.pipeline import DataConfig, MarkovTask
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = get_config("granite-3-2b").replace(
        name="granite-100m",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, kv_heads=args.d_model // 128,
        head_dim=0, d_ff=args.d_model * 4, vocab=args.vocab,
        attn_chunk=128,
    )
    model = build_model(cfg)
    print(f"params={model.n_params()/1e6:.1f}M  layers={cfg.n_layers} "
          f"d_model={cfg.d_model} vocab={cfg.vocab}")

    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, branching=4)
    print(f"markov loss floor ~{MarkovTask(data).entropy():.3f} nats")

    rep = train(
        model, steps=args.steps, data_cfg=data,
        opt=AdamWConfig(lr=3e-4, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 10)),
        accum=args.accum, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        log_every=20,
    )
    for s in sorted(rep.losses):
        print(f"step {s:4d}  loss {rep.losses[s]:.4f}")
    print(f"wall {rep.wall_s:.0f}s  stragglers {rep.straggler_steps} "
          f"resumed_from {rep.resumed_from}")


if __name__ == "__main__":
    main()

"""Int8 gradient compression with error feedback (EF-SGD style).

For cross-pod (DCN) gradient reduction: per-tensor max-abs scaling to int8,
with the quantization residual fed back into the next step so the long-run
bias vanishes. Two entry points:

  * compress/decompress + error-feedback transform — numerics library used
    by the train loop when `compress_grads=True` (models the wire format).
  * compressed_psum — a shard_map collective: quantize locally, integer
    all-reduce (sums of int8 fit int32 for <=2^23 participants), dequantize
    with the max of the scales. This is what runs on the `pod` axis in the
    multi-pod deployment: 4x fewer DCN bytes than fp32.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(grads, error_state):
    """Error-feedback compression: returns (decompressed grads, new error)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        dq = dequantize_int8(q, s)
        return dq.astype(g.dtype), (g32 - dq)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_g, new_e


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x, axis_name: str):
    """Quantized all-reduce for shard_map code (the pod/DCN axis)."""
    q, scale = quantize_int8(x.astype(jnp.float32))
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # conservative shared scale: max over participants
    scale = jax.lax.pmax(scale, axis_name)
    return dequantize_int8(total, scale).astype(x.dtype)

"""Self-contained AdamW with warmup-cosine schedule.

Parameters are stored fp32 and cast to the compute dtype inside the model, so
the optimizer state is exactly (params, mu, nu) — all sharded identically by
the logical-axis rules (ZeRO-style: the `embed`->data rule shards storage over
the data axis on top of tensor parallelism).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "mu": zeros,
        "nu": jax.tree.map(jnp.zeros_like, params),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def apply_updates(state: Dict[str, Any], grads, cfg: AdamWConfig):
    """One AdamW step. Returns (new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        step_dir = mhat / (jnp.sqrt(nhat) + cfg.eps)
        p = p - lr * (step_dir + cfg.weight_decay * p)
        return p, mu, nu

    flat_p, tdef = jax.tree.flatten(state["params"])
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    new_state = {
        "step": step,
        "params": jax.tree.unflatten(tdef, new_p),
        "mu": jax.tree.unflatten(tdef, new_mu),
        "nu": jax.tree.unflatten(tdef, new_nu),
    }
    return new_state, {"lr": lr, "grad_norm": gnorm}

"""Continuous-batching request scheduler over the KV block pool.

The policy mirrors the paper's dynamic coroutine scheduler (§III-D): a
*ready request* is a coroutine, the block pool is the context arena, and the
number of requests decoded per round is bounded by the pipeline depth
`core.autotune` solves for the paged decode `CoroSpec` — the serving-side
analogue of "keep exactly enough coroutines in flight to hide latency,
capped by the context the scratchpad can hold".

States:

  WAITING   - queued; admitted when the pool can hold its prompt
  PREFILL   - blocks allocated, prompt KV being written chunk by chunk
  RUNNING   - prefill complete, decoded every round
  FINISHED  - done; block references returned to the pool
  CANCELLED - terminal without completing: caller `cancel`, deadline
              expiry, or the engine aborting a stalled drain (ISSUE-9)
  FAILED    - terminal on error: a poisoned step quarantined the request,
              unresolvable pool pressure, or shed at an overflowing queue

Admission reserves copy-on-write headroom: a prefix match that ends
mid-block will fork the shared partial page on its very first suffix
write, so `admit` requires one spare free block beyond the fresh suffix
blocks whenever ``matched_tokens % block_size != 0`` — without it the
fork's `PoolExhausted` fires after the pages are claimed, when the matched
pages are refcounted >= 2 (unevictable) and there may be nobody left to
preempt. If even reclaiming around the *protected* match pages cannot
cover the need, the match itself is sacrificed: reclaim runs unprotected,
the prompt is re-matched against whatever survived, and admission retries
as a (partial or full) miss.

Rounds mix work under a **token budget** (`plan_round`): every running
request decodes one token (decode is never starved by prefill), and the
leftover budget is spent on prefill chunks of admitted-but-unfinished
prompts, oldest admission first. A long prompt therefore trickles through
several rounds instead of stalling every in-flight decode for one
monolithic prefill — the chunks and the decode steps share the same paged
pipeline and the same rounds.

Pool pressure resolves in two stages: first `reclaim` (the engine's hook
that evicts cache-only pages from the prefix index, LRU), then preemption —
the *latest-admitted* other in-flight request is evicted: its page
references are dropped and it re-queues at the front of the waiting line,
keeping everything it has generated so far (recompute-on-readmit — which,
with the prefix cache, usually turns into a cheap prefix hit on its own
surviving pages). Evicting the newest request never starves the oldest, so
every admitted request eventually finishes as long as the pool can hold a
single maximal request.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Callable, Deque, FrozenSet, List, Optional, Sequence, Tuple

from repro.obs import trace as obs_trace
from repro.serve.faults import NULL_INJECTOR
from repro.serve.kv_pager import KVPager, PoolExhausted
from repro.serve.prefix_cache import MISS, PrefixMatch


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    RUNNING = "running"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    FAILED = "failed"


TERMINAL_STATES = frozenset({RequestState.FINISHED, RequestState.CANCELLED,
                             RequestState.FAILED})


@dataclasses.dataclass
class Request:
    """One generation request flowing through the engine."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: RequestState = RequestState.WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    kv_len: int = 0                  # tokens with pool room reserved
    prefill_pos: int = 0             # context tokens whose KV is written
    matched_len: int = 0             # prefix-cache tokens reused (last admit)
    preemptions: int = 0
    admit_seq: int = -1              # order of the (latest) admission
    submit_s: float = 0.0            # wall clock at submit (engine stamps)
    first_token_s: Optional[float] = None
    last_emit_s: Optional[float] = None
    deadline_s: Optional[float] = None  # absolute perf_counter deadline
    stalls: int = 0                  # unresolvable-pressure requeues
    fault_count: int = 0             # consecutive failed steps (engine)
    error: Optional[str] = None      # what quarantined it (FAILED only)
    finish_reason: Optional[str] = None  # complete / cancelled / deadline /
    #                                      shed / stalled / fault / ...

    @property
    def context(self) -> List[int]:
        """Tokens to prefill on (re-)admission: prompt + generated so far."""
        return self.prompt + self.generated

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit -> first token wall time (None until the first token)."""
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submit_s


# reclaim hook: (blocks_needed, protect) -> blocks actually freed
ReclaimFn = Callable[[int, FrozenSet[int]], int]
# prefix lookup hook: context tokens -> PrefixMatch
MatchFn = Callable[[Sequence[int]], PrefixMatch]


class ContinuousBatchingScheduler:
    """Admit / evict / preempt on pool pressure; assemble budgeted rounds."""

    def __init__(self, pager: KVPager, max_in_flight: int, *,
                 token_budget: Optional[int] = None,
                 reclaim: Optional[ReclaimFn] = None,
                 faults=NULL_INJECTOR):
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got {max_in_flight}")
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        self.pager = pager
        self.max_in_flight = int(max_in_flight)
        self.token_budget = token_budget
        self.reclaim = reclaim
        self.faults = faults  # serve.faults hook ("preempt_refuse" site)
        self.waiting: Deque[Request] = deque()
        self.prefilling: List[Request] = []
        self.running: List[Request] = []
        self.preemptions = 0
        self._admit_seq = 0

    # ------------------------------------------------------------- intake

    def submit(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.prefilling or self.running)

    def in_flight(self) -> int:
        return len(self.prefilling) + len(self.running)

    # ---------------------------------------------------------- admission

    def _blocks_needed(self, ctxt: Sequence[int], m: PrefixMatch) -> int:
        """Free blocks an admission must see: the fresh suffix blocks, plus
        one spare when the match ends mid-block — the request's first
        suffix write copy-on-write forks that shared partial page, and the
        fork must not be left to fail *after* the pages are claimed (the
        matched page is then refcounted >= 2, hence unevictable, and with
        no other in-flight request there is nobody to preempt — the
        reproduced ISSUE-9 crash)."""
        fresh = self.pager.blocks_for(len(ctxt)) - len(m.blocks)
        if m.n_tokens % self.pager.block_size:
            fresh += 1
        return fresh

    def admit(self, match: Optional[MatchFn] = None) -> List[Request]:
        """Move waiting requests to PREFILL while the round has slots and
        the pool can hold their context. `match` (the engine's prefix-cache
        lookup) lets an admission reference already-resident prefix pages —
        only the suffix costs fresh blocks, and only the suffix is
        prefilled. FIFO: admission stops at the first request that does not
        fit even after reclaiming cache-only pages, so a large head request
        cannot be starved by small ones slipping past it."""
        admitted: List[Request] = []
        while self.waiting and self.in_flight() < self.max_in_flight:
            req = self.waiting[0]
            ctxt = req.context
            m = match(ctxt) if match is not None else MISS
            need = self._blocks_needed(ctxt, m)
            if need > self.pager.free_blocks and self.reclaim is not None:
                self.reclaim(need - self.pager.free_blocks,
                             frozenset(m.blocks))
                if need > self.pager.free_blocks and m.hit:
                    # the only reclaimable pages may be the protected match
                    # itself: give the match up, reclaim unprotected, and
                    # re-match against whatever survived
                    if self.reclaim(need - self.pager.free_blocks,
                                    frozenset()):
                        m = match(ctxt) if match is not None else MISS
                        need = self._blocks_needed(ctxt, m)
            if need > self.pager.free_blocks:
                break
            try:
                self.pager.alloc(req.rid, len(ctxt),
                                 prefix_blocks=m.blocks, prefix_len=m.n_tokens)
            except PoolExhausted:
                break  # injected fault mid-claim; retry next round
            self.waiting.popleft()
            req.kv_len = len(ctxt)
            req.prefill_pos = m.n_tokens
            req.matched_len = m.n_tokens
            req.state = RequestState.PREFILL
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.prefilling.append(req)
            admitted.append(req)
        return admitted

    # --------------------------------------------------------- preemption

    def _preempt_one(self, protect: Request) -> bool:
        """Evict the latest-admitted in-flight request other than `protect`."""
        if self.faults.fire("preempt_refuse", protect=protect.rid):
            return False  # injected: the victim is unpreemptable right now
        victims = [r for r in self.prefilling + self.running if r is not protect]
        if not victims:
            return False
        victim = max(victims, key=lambda r: r.admit_seq)
        # preemption is rare enough to fetch the tracer per event
        obs_trace.get_tracer().instant("preempt", rid=victim.rid,
                                       kv_len=victim.kv_len,
                                       state=victim.state.value)
        victim.preemptions += 1
        self.preemptions += 1
        self.requeue(victim)
        return True

    def requeue(self, req: Request) -> None:
        """Push an in-flight request back to the head of the waiting line
        (recompute-on-readmit): drop its page references and reset its
        prefill progress; everything generated so far is kept. Used by
        preemption and by the engine's stall path (unresolvable pressure)."""
        if self.pager.owns(req.rid):
            self.pager.free(req.rid)
        req.kv_len = 0
        req.prefill_pos = 0
        req.state = RequestState.WAITING
        if req in self.running:
            self.running.remove(req)
        elif req in self.prefilling:
            self.prefilling.remove(req)
        if req not in self.waiting:
            self.waiting.appendleft(req)

    def unreserve(self, req: Request) -> None:
        """Roll back `reserve_decode_slot` for a decode step that never
        executed (the round raised after reservations were made)."""
        if req.state is RequestState.RUNNING and self.pager.owns(req.rid):
            self.pager.pop_token(req.rid)

    def _under_pressure(self, req: Request, fn):
        """Run a pager operation, resolving `PoolExhausted` by reclaiming a
        cache-only page, then by preempting the newest other request; raises
        only when `req` *alone* overflows the pool. A caller iterating a
        round must re-check each request's state afterwards: resolving
        pressure for an early request may evict a later one."""
        while True:
            try:
                return fn()
            except PoolExhausted:
                if self.reclaim is not None and self.reclaim(1, frozenset()):
                    continue
                if not self._preempt_one(req):
                    raise

    def reserve_decode_slot(self, req: Request) -> int:
        """Reserve pool room for `req`'s next token; returns its position."""
        return self._under_pressure(
            req, lambda: self.pager.append_token(req.rid))

    def make_writable(self, req: Request, pos: int):
        """Copy-on-write guard before writing the KV row at `pos`: forks the
        containing page if it is shared. Returns the pager's (src, dst) copy
        order, or None."""
        return self._under_pressure(
            req, lambda: self.pager.ensure_writable(req.rid, pos))

    # ------------------------------------------------------------- rounds

    def plan_round(self, chunk: Optional[int]) -> Tuple[
            List[Request], List[Tuple[Request, int]]]:
        """One round's work under the token budget: every RUNNING request
        decodes (1 token each, never starved), then the leftover budget is
        spent on prefill chunks of at most `chunk` tokens (None: the whole
        remaining prompt), oldest admission first."""
        decodes = sorted(self.running, key=lambda r: r.admit_seq)
        left: Optional[int] = None
        if self.token_budget is not None:
            left = max(self.token_budget - len(decodes), 0)
        plans: List[Tuple[Request, int]] = []
        for req in sorted(self.prefilling, key=lambda r: r.admit_seq):
            if left is not None and left <= 0:
                break
            n = len(req.context) - req.prefill_pos
            if chunk is not None:
                n = min(n, chunk)
            if left is not None:
                n = min(n, left)
            if n > 0:
                plans.append((req, n))
                if left is not None:
                    left -= n
        return decodes, plans

    def round(self) -> List[Request]:
        """The requests decoding this round, oldest admission first."""
        return sorted(self.running, key=lambda r: r.admit_seq)

    def promote(self, req: Request) -> None:
        """Prefill complete: the request decodes from the next round on."""
        self.prefilling.remove(req)
        req.state = RequestState.RUNNING
        self.running.append(req)

    def finish(self, req: Request) -> None:
        self.retire(req, RequestState.FINISHED)

    def retire(self, req: Request,
               state: RequestState = RequestState.FINISHED) -> None:
        """Terminal transition from *any* queue (or none — a shed request
        never entered one): drop the request's page references and remove
        it from whichever collection holds it. `state` must be terminal."""
        if state not in TERMINAL_STATES:
            raise ValueError(f"retire needs a terminal state, got {state}")
        if self.pager.owns(req.rid):
            self.pager.free(req.rid)
        req.state = state
        if req in self.running:
            self.running.remove(req)
        elif req in self.prefilling:
            self.prefilling.remove(req)
        elif req in self.waiting:
            self.waiting.remove(req)

"""Continuous-batching request scheduler over the KV block pool.

The policy mirrors the paper's dynamic coroutine scheduler (§III-D): a
*ready request* is a coroutine, the block pool is the context arena, and the
number of requests decoded per round is bounded by the pipeline depth
`core.autotune` solves for the paged decode `CoroSpec` — the serving-side
analogue of "keep exactly enough coroutines in flight to hide latency,
capped by the context the scratchpad can hold".

States:

  WAITING  - queued; admitted when the pool can hold its prompt
  PREFILL  - blocks allocated, prompt KV being written chunk by chunk
  RUNNING  - prefill complete, decoded every round
  FINISHED - done; block references returned to the pool

Rounds mix work under a **token budget** (`plan_round`): every running
request decodes one token (decode is never starved by prefill), and the
leftover budget is spent on prefill chunks of admitted-but-unfinished
prompts, oldest admission first. A long prompt therefore trickles through
several rounds instead of stalling every in-flight decode for one
monolithic prefill — the chunks and the decode steps share the same paged
pipeline and the same rounds.

Pool pressure resolves in two stages: first `reclaim` (the engine's hook
that evicts cache-only pages from the prefix index, LRU), then preemption —
the *latest-admitted* other in-flight request is evicted: its page
references are dropped and it re-queues at the front of the waiting line,
keeping everything it has generated so far (recompute-on-readmit — which,
with the prefix cache, usually turns into a cheap prefix hit on its own
surviving pages). Evicting the newest request never starves the oldest, so
every admitted request eventually finishes as long as the pool can hold a
single maximal request.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Callable, Deque, FrozenSet, List, Optional, Sequence, Tuple

from repro.obs import trace as obs_trace
from repro.serve.kv_pager import KVPager, PoolExhausted
from repro.serve.prefix_cache import MISS, PrefixMatch


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request flowing through the engine."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: RequestState = RequestState.WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    kv_len: int = 0                  # tokens with pool room reserved
    prefill_pos: int = 0             # context tokens whose KV is written
    matched_len: int = 0             # prefix-cache tokens reused (last admit)
    preemptions: int = 0
    admit_seq: int = -1              # order of the (latest) admission
    submit_s: float = 0.0            # wall clock at submit (engine stamps)
    first_token_s: Optional[float] = None
    last_emit_s: Optional[float] = None

    @property
    def context(self) -> List[int]:
        """Tokens to prefill on (re-)admission: prompt + generated so far."""
        return self.prompt + self.generated

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit -> first token wall time (None until the first token)."""
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submit_s


# reclaim hook: (blocks_needed, protect) -> blocks actually freed
ReclaimFn = Callable[[int, FrozenSet[int]], int]
# prefix lookup hook: context tokens -> PrefixMatch
MatchFn = Callable[[Sequence[int]], PrefixMatch]


class ContinuousBatchingScheduler:
    """Admit / evict / preempt on pool pressure; assemble budgeted rounds."""

    def __init__(self, pager: KVPager, max_in_flight: int, *,
                 token_budget: Optional[int] = None,
                 reclaim: Optional[ReclaimFn] = None):
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got {max_in_flight}")
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        self.pager = pager
        self.max_in_flight = int(max_in_flight)
        self.token_budget = token_budget
        self.reclaim = reclaim
        self.waiting: Deque[Request] = deque()
        self.prefilling: List[Request] = []
        self.running: List[Request] = []
        self.preemptions = 0
        self._admit_seq = 0

    # ------------------------------------------------------------- intake

    def submit(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.prefilling or self.running)

    def in_flight(self) -> int:
        return len(self.prefilling) + len(self.running)

    # ---------------------------------------------------------- admission

    def admit(self, match: Optional[MatchFn] = None) -> List[Request]:
        """Move waiting requests to PREFILL while the round has slots and
        the pool can hold their context. `match` (the engine's prefix-cache
        lookup) lets an admission reference already-resident prefix pages —
        only the suffix costs fresh blocks, and only the suffix is
        prefilled. FIFO: admission stops at the first request that does not
        fit even after reclaiming cache-only pages, so a large head request
        cannot be starved by small ones slipping past it."""
        admitted: List[Request] = []
        while self.waiting and self.in_flight() < self.max_in_flight:
            req = self.waiting[0]
            ctxt = req.context
            m = match(ctxt) if match is not None else MISS
            fresh = self.pager.blocks_for(len(ctxt)) - len(m.blocks)
            shortfall = fresh - self.pager.free_blocks
            if shortfall > 0 and self.reclaim is not None:
                self.reclaim(shortfall, frozenset(m.blocks))
            if fresh > self.pager.free_blocks:
                break
            self.waiting.popleft()
            self.pager.alloc(req.rid, len(ctxt),
                             prefix_blocks=m.blocks, prefix_len=m.n_tokens)
            req.kv_len = len(ctxt)
            req.prefill_pos = m.n_tokens
            req.matched_len = m.n_tokens
            req.state = RequestState.PREFILL
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.prefilling.append(req)
            admitted.append(req)
        return admitted

    # --------------------------------------------------------- preemption

    def _preempt_one(self, protect: Request) -> bool:
        """Evict the latest-admitted in-flight request other than `protect`."""
        victims = [r for r in self.prefilling + self.running if r is not protect]
        if not victims:
            return False
        victim = max(victims, key=lambda r: r.admit_seq)
        # preemption is rare enough to fetch the tracer per event
        obs_trace.get_tracer().instant("preempt", rid=victim.rid,
                                       kv_len=victim.kv_len,
                                       state=victim.state.value)
        self.pager.free(victim.rid)
        victim.kv_len = 0
        victim.prefill_pos = 0
        victim.state = RequestState.WAITING
        victim.preemptions += 1
        self.preemptions += 1
        if victim in self.running:
            self.running.remove(victim)
        else:
            self.prefilling.remove(victim)
        self.waiting.appendleft(victim)
        return True

    def _under_pressure(self, req: Request, fn):
        """Run a pager operation, resolving `PoolExhausted` by reclaiming a
        cache-only page, then by preempting the newest other request; raises
        only when `req` *alone* overflows the pool. A caller iterating a
        round must re-check each request's state afterwards: resolving
        pressure for an early request may evict a later one."""
        while True:
            try:
                return fn()
            except PoolExhausted:
                if self.reclaim is not None and self.reclaim(1, frozenset()):
                    continue
                if not self._preempt_one(req):
                    raise

    def reserve_decode_slot(self, req: Request) -> int:
        """Reserve pool room for `req`'s next token; returns its position."""
        return self._under_pressure(
            req, lambda: self.pager.append_token(req.rid))

    def make_writable(self, req: Request, pos: int):
        """Copy-on-write guard before writing the KV row at `pos`: forks the
        containing page if it is shared. Returns the pager's (src, dst) copy
        order, or None."""
        return self._under_pressure(
            req, lambda: self.pager.ensure_writable(req.rid, pos))

    # ------------------------------------------------------------- rounds

    def plan_round(self, chunk: Optional[int]) -> Tuple[
            List[Request], List[Tuple[Request, int]]]:
        """One round's work under the token budget: every RUNNING request
        decodes (1 token each, never starved), then the leftover budget is
        spent on prefill chunks of at most `chunk` tokens (None: the whole
        remaining prompt), oldest admission first."""
        decodes = sorted(self.running, key=lambda r: r.admit_seq)
        left: Optional[int] = None
        if self.token_budget is not None:
            left = max(self.token_budget - len(decodes), 0)
        plans: List[Tuple[Request, int]] = []
        for req in sorted(self.prefilling, key=lambda r: r.admit_seq):
            if left is not None and left <= 0:
                break
            n = len(req.context) - req.prefill_pos
            if chunk is not None:
                n = min(n, chunk)
            if left is not None:
                n = min(n, left)
            if n > 0:
                plans.append((req, n))
                if left is not None:
                    left -= n
        return decodes, plans

    def round(self) -> List[Request]:
        """The requests decoding this round, oldest admission first."""
        return sorted(self.running, key=lambda r: r.admit_seq)

    def promote(self, req: Request) -> None:
        """Prefill complete: the request decodes from the next round on."""
        self.prefilling.remove(req)
        req.state = RequestState.RUNNING
        self.running.append(req)

    def finish(self, req: Request) -> None:
        self.pager.free(req.rid)
        req.state = RequestState.FINISHED
        if req in self.running:
            self.running.remove(req)
        else:
            self.prefilling.remove(req)

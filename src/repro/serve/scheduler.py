"""Continuous-batching request scheduler over the KV block pool.

The policy mirrors the paper's dynamic coroutine scheduler (§III-D): a
*ready request* is a coroutine, the block pool is the context arena, and the
number of requests decoded per round is bounded by the pipeline depth
`core.autotune` solves for the paged decode `CoroSpec` — the serving-side
analogue of "keep exactly enough coroutines in flight to hide latency,
capped by the context the scratchpad can hold".

States:

  WAITING  - queued; admitted when the pool can hold its prompt
  RUNNING  - blocks allocated, decoded every round
  FINISHED - done; blocks returned to the pool

Preemption: when a running request needs a page and the pool is dry, the
*latest-admitted* other running request is evicted — its pages are freed and
it re-queues at the front of the waiting line, keeping everything it has
generated so far (recompute-on-readmit: its next prefill covers prompt +
generated). Evicting the newest request is the policy that never starves
the oldest one, so every admitted request eventually finishes as long as
the pool can hold a single maximal request.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, List

from repro.serve.kv_pager import KVPager, PoolExhausted


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request flowing through the engine."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: RequestState = RequestState.WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    kv_len: int = 0                  # tokens with KV stored in the pool
    preemptions: int = 0
    admit_seq: int = -1              # order of the (latest) admission

    @property
    def context(self) -> List[int]:
        """Tokens to prefill on (re-)admission: prompt + generated so far."""
        return self.prompt + self.generated

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ContinuousBatchingScheduler:
    """Admit / evict / preempt on pool pressure; assemble decode rounds."""

    def __init__(self, pager: KVPager, max_in_flight: int):
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got {max_in_flight}")
        self.pager = pager
        self.max_in_flight = int(max_in_flight)
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.preemptions = 0
        self._admit_seq = 0

    # ------------------------------------------------------------- intake

    def submit(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ---------------------------------------------------------- admission

    def admit(self) -> List[Request]:
        """Move waiting requests to RUNNING while the round has slots and
        the pool can hold their context. Returns the newly admitted batch
        (the engine prefills them). FIFO: admission stops at the first
        request that does not fit, so a large head request cannot be
        starved by small ones slipping past it."""
        admitted: List[Request] = []
        while self.waiting and len(self.running) < self.max_in_flight:
            req = self.waiting[0]
            n_ctx = len(req.context)
            if not self.pager.can_alloc(n_ctx):
                break
            self.waiting.popleft()
            self.pager.alloc(req.rid, n_ctx)
            req.kv_len = n_ctx
            req.state = RequestState.RUNNING
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.running.append(req)
            admitted.append(req)
        return admitted

    # --------------------------------------------------------- preemption

    def _preempt_one(self, protect: Request) -> bool:
        """Evict the latest-admitted running request other than `protect`."""
        victims = [r for r in self.running if r is not protect]
        if not victims:
            return False
        victim = max(victims, key=lambda r: r.admit_seq)
        self.pager.free(victim.rid)
        victim.kv_len = 0
        victim.state = RequestState.WAITING
        victim.preemptions += 1
        self.preemptions += 1
        self.running.remove(victim)
        self.waiting.appendleft(victim)
        return True

    def reserve_decode_slot(self, req: Request) -> int:
        """Reserve pool room for `req`'s next token, preempting on pressure.

        Returns the token's write position. Raises `PoolExhausted` only if
        `req` *alone* overflows the pool (no victims left to evict) — size
        the pool for at least one maximal request. A caller iterating a
        round must re-check each request's state first: reserving for an
        early request may evict a later one from the same round."""
        while True:
            try:
                return self.pager.append_token(req.rid)
            except PoolExhausted:
                if not self._preempt_one(req):
                    # nothing left to evict: the request alone overflows the
                    # pool — surface it rather than spinning
                    raise

    # ------------------------------------------------------------- rounds

    def round(self) -> List[Request]:
        """The requests decoding this round, oldest admission first."""
        return sorted(self.running, key=lambda r: r.admit_seq)

    def finish(self, req: Request) -> None:
        self.pager.free(req.rid)
        req.state = RequestState.FINISHED
        self.running.remove(req)

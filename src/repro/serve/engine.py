"""Paged-KV continuous-batching serving engine on the coroutine substrate.

Every round the scheduler plans work under a token budget: all running
requests decode one token through a single jitted
`models.lm.decode_step_paged` (per-request ragged positions, one fixed round
width, pools donated so the cache updates in place), and the leftover budget
drives **chunked prefill** — admitted prompts trickle through
`models.lm.prefill_chunk_paged` a fixed-size chunk at a time, writing KV
directly into their pages instead of the old dense-prefill-then-scatter.
The round width is the pipeline depth `core.autotune` solves for the paged
decode `CoroSpec`: the scheduler keeps as many request-coroutines in flight
as the tuned pipeline keeps page-tiles in flight.

Shared prompt prefixes dedup through the radix **prefix cache**
(`serve/prefix_cache.py`): admission looks the prompt up, already-resident
pages are refcounted into the new request's table, and only the suffix is
prefilled. Pages a request would write mid-block are copy-on-write forked
first (`KVPager.ensure_writable` + a physical page copy here). Under pool
pressure the engine reclaims least-recently-hit cache-only pages before the
scheduler resorts to preemption.

The decode math runs through the jnp twin (`models.common`), which jits on
any backend; `kernels/decode_attention.paged_flash_decode` is the TPU
pipeline the round rides there (validated for parity in
tests/test_serve_paged.py, benchmarked in benchmarks/kernel_bench.py).

Because freed pages are reused immediately, the aggregate KV served over a
workload routinely exceeds what the same HBM held as a dense
``[batch, max_len]`` cache — `stats()["kv_oversubscription"]` reports the
ratio.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, FrozenSet, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import autotune
from repro.core.machine import get_machine
from repro.kernels.decode_attention.decode_attention import paged_decode_spec
from repro.models import build_model
from repro.serve.kv_pager import KVPager
from repro.serve.prefill import ChunkedPrefiller
from repro.serve.prefix_cache import MISS, PrefixCache, PrefixMatch
from repro.serve.scheduler import (
    ContinuousBatchingScheduler,
    Request,
    RequestState,
)
from repro.sharding import NULL_CTX, ShardingCtx


def latency_report(samples_s: List[float]) -> Dict[str, float]:
    """The one latency-stats dict every serving path reports: p50/p99/mean
    of a per-token latency sample list, in milliseconds. Shared by the
    paged engine (`stats`) and both engines in `launch.serve`."""
    if not samples_s:
        return {"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
    arr = np.asarray(samples_s) * 1e3
    return {"p50_ms": round(float(np.percentile(arr, 50)), 3),
            "p99_ms": round(float(np.percentile(arr, 99)), 3),
            "mean_ms": round(float(arr.mean()), 3)}


class PagedServingEngine:
    """Continuous batching over a paged KV pool for one model instance."""

    def __init__(self, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX, *,
                 block_size: int = 16, num_blocks: int = 64,
                 max_in_flight: Optional[int] = None,
                 prefix_cache: bool = True,
                 prefill_chunk: int = 32,
                 token_budget: Optional[int] = None,
                 params: Optional[Any] = None, seed: int = 0,
                 on_token: Optional[Callable[[Request, int], None]] = None,
                 on_finish: Optional[Callable[[Request], None]] = None):
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.cfg = cfg
        self.ctx = ctx
        self.model = build_model(cfg, ctx)
        if not self.model.supports_paged_decode():
            raise ValueError(
                f"arch {cfg.name!r} (family={cfg.family}, sliding_window="
                f"{cfg.sliding_window}) needs the dense/ring/recurrent cache "
                "path; the paged engine serves plain-attention archs")
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed)))
        self.pager = KVPager(num_blocks, block_size)
        self.prefix_cache: Optional[PrefixCache] = (
            PrefixCache(self.pager) if prefix_cache else None)
        kh, hd, g = cfg.kv_heads, cfg.resolved_head_dim, cfg.n_heads // cfg.kv_heads

        # scheduler <-> autotune coupling: in-flight requests per round =
        # the solved pipeline depth of the paged decode spec (clamped to 2+)
        spec = paged_decode_spec(block_size, kh, g, hd, jnp.dtype(cfg.dtype),
                                 max_blocks=max(num_blocks, 1))
        self.solved_depth = autotune.choose_depth(
            spec.profile(), kernel="paged_decode", vars=spec.all_vars())
        # a round can't usefully exceed one block-owning request per block
        self.round_width = int(max_in_flight
                               or min(max(2, self.solved_depth), num_blocks))
        self.prefill_chunk = int(prefill_chunk)
        # budget: every running request decodes, plus one chunk's worth of
        # prefill trickles alongside — decode is never starved, prefill
        # never stalls a round for a whole prompt
        self.token_budget = int(token_budget
                                or self.round_width + self.prefill_chunk)
        self.scheduler = ContinuousBatchingScheduler(
            self.pager, self.round_width,
            token_budget=self.token_budget, reclaim=self._reclaim)
        self.prefiller = ChunkedPrefiller(self.model, block_size)

        shape = (cfg.n_layers, self.pager.physical_blocks, block_size, kh, hd)
        self.k_pools = jnp.zeros(shape, jnp.dtype(cfg.dtype))
        self.v_pools = jnp.zeros(shape, jnp.dtype(cfg.dtype))

        self.on_token = on_token
        self.on_finish = on_finish
        self._requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._decode_fn = None                  # jit cache keyed by table width
        self._decode_fn_width = 0
        self._decode_fresh = False
        self.rounds = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.prefix_hits = 0
        self.prefix_tokens = 0
        self.blocks_shared = 0
        self.cow_forks = 0
        self.token_latencies_s: List[float] = []
        self.tbt_s: List[float] = []            # inter-token gaps (fairness)
        self.finished: List[Request] = []

    # -------------------------------------------------------------- intake

    def submit(self, prompt_tokens, max_new_tokens: int) -> int:
        """Queue one request. Returns its id; results stream via callbacks
        and land on `request(rid).generated`."""
        prompt = [int(t) for t in np.asarray(prompt_tokens).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        max_total = len(prompt) + max_new_tokens
        if self.pager.blocks_for(max_total) > self.pager.num_blocks:
            raise ValueError(
                f"request needs {self.pager.blocks_for(max_total)} blocks at "
                f"full length; pool has {self.pager.num_blocks}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=int(max_new_tokens))
        req.submit_s = time.perf_counter()
        self._requests[rid] = req
        self.scheduler.submit(req)
        return rid

    def request(self, rid: int) -> Request:
        return self._requests[rid]

    # ------------------------------------------------------ prefix plumbing

    @property
    def _prefill_fns(self) -> Dict[Any, Any]:
        """The chunk-step jit cache — bounded by pow2 length bucketing
        (tests assert its size stays logarithmic in prompt length)."""
        return self.prefiller._fns

    def _match(self, tokens) -> PrefixMatch:
        if self.prefix_cache is None:
            return MISS
        return self.prefix_cache.match(tokens)

    def _reclaim(self, n_blocks: int, protect: FrozenSet[int]) -> int:
        """Scheduler pressure hook: drop LRU cache-only pages."""
        if self.prefix_cache is None:
            return 0
        return len(self.prefix_cache.evict(n_blocks, protect))

    def _copy_page(self, src: int, dst: int) -> None:
        """Materialise a copy-on-write fork in the physical pools."""
        self.k_pools = self.k_pools.at[:, dst].set(self.k_pools[:, src])
        self.v_pools = self.v_pools.at[:, dst].set(self.v_pools[:, src])
        self.cow_forks += 1

    def _make_writable(self, req: Request, pos: int) -> None:
        copy = self.scheduler.make_writable(req, pos)
        if copy is not None:
            self._copy_page(*copy)

    # ------------------------------------------------------------- prefill

    def _prefill_chunk_step(self, req: Request, n: int) -> None:
        """Run one `n`-token chunk of `req`'s prefill; on the last chunk,
        emit the first generated token and promote (or finish)."""
        ctxt = req.context
        start = req.prefill_pos
        n = min(n, len(ctxt) - start)
        if n <= 0:
            return
        # the chunk's first page may be shared (a partial-block prefix hit):
        # fork it before writing rows into it
        self._make_writable(req, start)
        if req.state is not RequestState.PREFILL:
            return  # the fork's pressure resolution preempted this request
        tw = self._table_width()
        table = self.pager.padded_table(req.rid, tw)
        t0 = time.perf_counter()
        logits, self.k_pools, self.v_pools, _ = self.prefiller.run_chunk(
            self.params, self.k_pools, self.v_pools,
            ctxt[start:start + n], table, start, n)
        self.prefill_s += time.perf_counter() - t0
        req.prefill_pos = start + n
        if self.prefix_cache is not None:
            self.prefix_cache.insert(ctxt[:req.prefill_pos],
                                     self.pager.block_table(req.rid))
        if req.prefill_pos >= len(ctxt):
            first = int(jnp.argmax(logits[0]))
            self._emit(req, first)
            if req.done:  # max_new_tokens == 1: satisfied by this token
                self.scheduler.finish(req)
                self.finished.append(req)
                if self.on_finish:
                    self.on_finish(req)
            else:
                self.scheduler.promote(req)

    def _emit(self, req: Request, token: int) -> None:
        now = time.perf_counter()
        if req.first_token_s is None:
            req.first_token_s = now
        elif req.last_emit_s is not None:
            self.tbt_s.append(now - req.last_emit_s)
        req.last_emit_s = now
        req.generated.append(token)
        if self.on_token:
            self.on_token(req, token)

    # -------------------------------------------------------------- decode

    def _decode(self, table_width: int):
        if self._decode_fn is None or table_width != self._decode_fn_width:
            model = self.model

            def step(params, k_pools, v_pools, tokens, tables, lengths):
                logits, k_pools, v_pools = model.decode_step_paged(
                    params, k_pools, v_pools, tables, lengths,
                    {"tokens": tokens})
                return jnp.argmax(logits[:, -1], axis=-1), k_pools, v_pools

            self._decode_fn = jax.jit(step, donate_argnums=(1, 2))
            self._decode_fn_width = table_width
            # the next round's wall clock includes jit compile: keep it out
            # of the transfer-telemetry feedback store
            self._decode_fresh = True
        return self._decode_fn

    def _table_width(self) -> int:
        """Static block-table width: every request's table padded to the
        worst case any submitted request can reach, so the jit is stable
        across rounds of one workload."""
        need = max((self.pager.blocks_for(len(r.prompt) + r.max_new_tokens)
                    for r in self._requests.values()), default=1)
        return max(need, 1)

    def _decode_round(self, active: List[Request]) -> int:
        """Decode one token for every (still-)running request in `active`."""
        # reserve pool room for each request's next token; reserving may
        # preempt later-admitted members of this same round, and writing
        # mid-block may copy-on-write fork a page the prefix cache shares
        writable: List[Request] = []
        for req in active:
            if req.state is not RequestState.RUNNING:
                continue  # preempted by an earlier reservation
            pos = self.scheduler.reserve_decode_slot(req)
            if req.state is RequestState.RUNNING:
                self._make_writable(req, pos)
            writable.append(req)
        writable = [r for r in writable if r.state is RequestState.RUNNING]
        if not writable:
            return 0

        width = self.round_width
        tw = self._table_width()
        tokens = np.zeros((width, 1), np.int32)
        tables = np.zeros((width, tw), np.int32)   # garbage page 0 padding
        lengths = np.zeros((width,), np.int32)
        for i, req in enumerate(writable):
            tokens[i, 0] = req.generated[-1]
            tables[i] = self.pager.padded_table(req.rid, tw)
            # pager length already counts the reserved slot; the model wants
            # the pre-write count (the new row's position)
            lengths[i] = self.pager.length(req.rid) - 1

        decode = self._decode(tw)
        t0 = time.perf_counter()
        nxt, self.k_pools, self.v_pools = decode(
            self.params, self.k_pools, self.v_pools,
            jnp.asarray(tokens), jnp.asarray(tables), jnp.asarray(lengths))
        nxt = np.asarray(jax.block_until_ready(nxt))
        dt = time.perf_counter() - t0
        self.decode_s += dt

        # always-on transfer telemetry (ISSUE-6): every decode round feeds
        # the same (machine, kernel) store the paged kernel's pipeline does —
        # wall clock over the KV page-tiles this round actually attended
        if self._decode_fresh:
            self._decode_fresh = False  # round paid jit compile; don't record
        else:
            tiles = sum(self.pager.blocks_for(int(n) + 1)
                        for n in (lengths[i] for i in range(len(writable))))
            if autotune.telemetry_enabled() and tiles:
                autotune.record_transfer("paged_decode", dt / tiles)

        for i, req in enumerate(writable):
            req.kv_len = self.pager.length(req.rid)
            self._emit(req, int(nxt[i]))
            self.token_latencies_s.append(dt)
            if req.done:
                self.scheduler.finish(req)
                self.finished.append(req)
                if self.on_finish:
                    self.on_finish(req)
        return len(writable)

    # --------------------------------------------------------------- round

    def step_round(self) -> int:
        """One budgeted scheduler round: admit (with prefix lookup), decode
        one token for every running request, then spend the leftover budget
        on prefill chunks. Returns tokens emitted this round."""
        for req in self.scheduler.admit(match=self._match):
            if req.matched_len > 0:
                self.prefix_hits += 1
                self.prefix_tokens += req.matched_len
                self.blocks_shared += self.pager.blocks_for(req.matched_len)

        decodes, plans = self.scheduler.plan_round(self.prefill_chunk)
        emitted = self._decode_round(decodes)
        for req, n in plans:
            if req.state is not RequestState.PREFILL:
                continue  # preempted resolving an earlier request's pressure
            before = len(req.generated)
            self._prefill_chunk_step(req, n)
            emitted += len(req.generated) - before
        self.rounds += 1
        return emitted

    # ----------------------------------------------------------------- run

    def run(self, max_rounds: int = 100_000) -> Dict[str, Any]:
        """Serve until every submitted request finishes. Returns stats."""
        rounds = 0
        while self.scheduler.has_work():
            if rounds >= max_rounds:
                raise RuntimeError(f"no convergence in {max_rounds} rounds")
            self.step_round()
            rounds += 1
        self.pager.check_invariants(
            self.prefix_cache.block_refs() if self.prefix_cache else None)
        return self.stats()

    def stats(self) -> Dict[str, Any]:
        decoded = len(self.token_latencies_s)
        agg_kv = sum(len(r.prompt) + len(r.generated) for r in self.finished)
        pool_tokens = self.pager.pool_tokens
        ttft = [r.ttft_s for r in self.finished if r.ttft_s is not None]
        out = {
            "engine": "paged",
            "machine": get_machine().name,
            "requests": len(self._requests),
            "completed": len(self.finished),
            "rounds": self.rounds,
            "preemptions": self.scheduler.preemptions,
            "round_width": self.round_width,
            "solved_depth": self.solved_depth,
            "token_budget": self.token_budget,
            "prefill_chunk": self.prefill_chunk,
            "prefill_chunks": self.prefiller.chunks_run,
            "block_size": self.pager.block_size,
            "num_blocks": self.pager.num_blocks,
            "pool_tokens": pool_tokens,
            "blocks_allocated": self.pager.blocks_allocated,
            "aggregate_kv_tokens": agg_kv,
            "kv_oversubscription": round(agg_kv / max(pool_tokens, 1), 2),
            "prefix_cache": self.prefix_cache is not None,
            "prefix_hits": self.prefix_hits,
            "prefix_tokens": self.prefix_tokens,
            "blocks_shared": self.blocks_shared,
            "cow_forks": self.cow_forks,
            "cache_blocks": (len(self.prefix_cache)
                             if self.prefix_cache else 0),
            "cache_evictions": (self.prefix_cache.evictions
                                if self.prefix_cache else 0),
            "prefill_s": round(self.prefill_s, 3),
            "decode_s": round(self.decode_s, 3),
            "decode_tok_per_s": round(decoded / max(self.decode_s, 1e-9), 1),
            "ttft_p50_ms": latency_report(ttft)["p50_ms"],
            "ttft_p99_ms": latency_report(ttft)["p99_ms"],
            "tbt_p50_ms": latency_report(self.tbt_s)["p50_ms"],
            "tbt_p99_ms": latency_report(self.tbt_s)["p99_ms"],
        }
        out.update(latency_report(self.token_latencies_s))
        if self.finished:
            out["sample_tokens"] = self.finished[0].generated[:8]
        return out

"""Paged-KV continuous-batching serving engine on the coroutine substrate.

Drives prefill-then-decode over the block pool: every round the scheduler
admits what fits, each admitted request is prefilled (its prompt KV is
scattered into its pages), and all running requests decode one token
through a single jitted `models.lm.decode_step_paged` — per-request ragged
positions, one fixed round width, pools donated so the cache updates in
place. The round width is the pipeline depth `core.autotune` solves for the
paged decode `CoroSpec`: the scheduler keeps as many request-coroutines in
flight as the tuned pipeline keeps page-tiles in flight.

The decode math runs through the jnp twin (`models.common`), which jits on
any backend; `kernels/decode_attention.paged_flash_decode` is the TPU
pipeline the round rides there (validated for parity in
tests/test_serve_paged.py, benchmarked in benchmarks/kernel_bench.py).

Because freed pages are reused immediately, the aggregate KV served over a
workload routinely exceeds what the same HBM held as a dense
``[batch, max_len]`` cache — `stats()["kv_oversubscription"]` reports the
ratio.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import autotune
from repro.core.machine import get_machine
from repro.kernels.decode_attention.decode_attention import paged_decode_spec
from repro.models import build_model
from repro.serve.kv_pager import KVPager
from repro.serve.scheduler import (
    ContinuousBatchingScheduler,
    Request,
    RequestState,
)
from repro.sharding import NULL_CTX, ShardingCtx


def latency_report(samples_s: List[float]) -> Dict[str, float]:
    """The one latency-stats dict every serving path reports: p50/p99/mean
    of a per-token latency sample list, in milliseconds. Shared by the
    paged engine (`stats`) and both engines in `launch.serve`."""
    if not samples_s:
        return {"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
    arr = np.asarray(samples_s) * 1e3
    return {"p50_ms": round(float(np.percentile(arr, 50)), 3),
            "p99_ms": round(float(np.percentile(arr, 99)), 3),
            "mean_ms": round(float(arr.mean()), 3)}


class PagedServingEngine:
    """Continuous batching over a paged KV pool for one model instance."""

    def __init__(self, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX, *,
                 block_size: int = 16, num_blocks: int = 64,
                 max_in_flight: Optional[int] = None,
                 params: Optional[Any] = None, seed: int = 0,
                 on_token: Optional[Callable[[Request, int], None]] = None,
                 on_finish: Optional[Callable[[Request], None]] = None):
        self.cfg = cfg
        self.ctx = ctx
        self.model = build_model(cfg, ctx)
        if not self.model.supports_paged_decode():
            raise ValueError(
                f"arch {cfg.name!r} (family={cfg.family}, sliding_window="
                f"{cfg.sliding_window}) needs the dense/ring/recurrent cache "
                "path; the paged engine serves plain-attention archs")
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed)))
        self.pager = KVPager(num_blocks, block_size)
        kh, hd, g = cfg.kv_heads, cfg.resolved_head_dim, cfg.n_heads // cfg.kv_heads

        # scheduler <-> autotune coupling: in-flight requests per round =
        # the solved pipeline depth of the paged decode spec (clamped to 2+)
        spec = paged_decode_spec(block_size, kh, g, hd, jnp.dtype(cfg.dtype),
                                 max_blocks=max(num_blocks, 1))
        self.solved_depth = autotune.choose_depth(
            spec.profile(), kernel="paged_decode", vars=spec.all_vars())
        # a round can't usefully exceed one block-owning request per block
        self.round_width = int(max_in_flight
                               or min(max(2, self.solved_depth), num_blocks))
        self.scheduler = ContinuousBatchingScheduler(self.pager, self.round_width)

        shape = (cfg.n_layers, self.pager.physical_blocks, block_size, kh, hd)
        self.k_pools = jnp.zeros(shape, jnp.dtype(cfg.dtype))
        self.v_pools = jnp.zeros(shape, jnp.dtype(cfg.dtype))

        self.on_token = on_token
        self.on_finish = on_finish
        self._requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._prefill_fns: Dict[int, Any] = {}  # jit cache keyed by padded len
        self._decode_fn = None                  # jit cache keyed by table width
        self._decode_fn_width = 0
        self._decode_fresh = False
        self.rounds = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.token_latencies_s: List[float] = []
        self.finished: List[Request] = []

    # -------------------------------------------------------------- intake

    def submit(self, prompt_tokens, max_new_tokens: int) -> int:
        """Queue one request. Returns its id; results stream via callbacks
        and land on `request(rid).generated`."""
        prompt = [int(t) for t in np.asarray(prompt_tokens).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        max_total = len(prompt) + max_new_tokens
        if self.pager.blocks_for(max_total) > self.pager.num_blocks:
            raise ValueError(
                f"request needs {self.pager.blocks_for(max_total)} blocks at "
                f"full length; pool has {self.pager.num_blocks}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=int(max_new_tokens))
        self._requests[rid] = req
        self.scheduler.submit(req)
        return rid

    def request(self, rid: int) -> Request:
        return self._requests[rid]

    # ------------------------------------------------------------- prefill

    def _prefill_fn(self, padded: int):
        fn = self._prefill_fns.get(padded)
        if fn is None:
            fn = jax.jit(lambda p, b: self.model.prefill(p, b, pad_to=padded))
            self._prefill_fns[padded] = fn
        return fn

    def _prefill(self, req: Request) -> None:
        """Run the prompt (context) through the model and scatter its KV
        into the request's pages; sample the first new token."""
        ctx_tokens = req.context
        n = len(ctx_tokens)
        blk = self.pager.block_size
        padded = self.pager.blocks_for(n) * blk
        batch = {"tokens": jnp.asarray([ctx_tokens], jnp.int32),
                 "positions": jnp.arange(n, dtype=jnp.int32)[None]}
        t0 = time.perf_counter()
        cache, logits = self._prefill_fn(padded)(self.params, batch)
        k = cache["layers"]["k"]  # [L, 1, padded, KH, D]
        v = cache["layers"]["v"]
        L, _, s_pad, kh, hd = k.shape
        nb = s_pad // blk
        bids = jnp.asarray(self.pager.block_table(req.rid)[:nb], jnp.int32)
        self.k_pools = self.k_pools.at[:, bids].set(
            k.reshape(L, nb, blk, kh, hd).astype(self.k_pools.dtype))
        self.v_pools = self.v_pools.at[:, bids].set(
            v.reshape(L, nb, blk, kh, hd).astype(self.v_pools.dtype))
        first = int(jnp.argmax(logits[0, -1]))
        jax.block_until_ready(self.k_pools)
        self.prefill_s += time.perf_counter() - t0
        self._emit(req, first)

    def _emit(self, req: Request, token: int) -> None:
        req.generated.append(token)
        if self.on_token:
            self.on_token(req, token)

    # -------------------------------------------------------------- decode

    def _decode(self, table_width: int):
        if self._decode_fn is None or table_width != self._decode_fn_width:
            model = self.model

            def step(params, k_pools, v_pools, tokens, tables, lengths):
                logits, k_pools, v_pools = model.decode_step_paged(
                    params, k_pools, v_pools, tables, lengths,
                    {"tokens": tokens})
                return jnp.argmax(logits[:, -1], axis=-1), k_pools, v_pools

            self._decode_fn = jax.jit(step, donate_argnums=(1, 2))
            self._decode_fn_width = table_width
            # the next round's wall clock includes jit compile: keep it out
            # of the transfer-telemetry feedback store
            self._decode_fresh = True
        return self._decode_fn

    def _table_width(self) -> int:
        """Static block-table width: every request's table padded to the
        worst case any submitted request can reach, so the jit is stable
        across rounds of one workload."""
        need = max((self.pager.blocks_for(len(r.prompt) + r.max_new_tokens)
                    for r in self._requests.values()), default=1)
        return max(need, 1)

    def step_round(self) -> int:
        """One scheduler round: admit + prefill, then decode one token for
        every running request. Returns tokens emitted this round."""
        for req in self.scheduler.admit():
            self._prefill(req)
            if req.done:  # max_new_tokens == 1: satisfied by the prefill token
                self.scheduler.finish(req)
                self.finished.append(req)
                if self.on_finish:
                    self.on_finish(req)

        active = [r for r in self.scheduler.round()]
        # reserve pool room for each request's next token; reserving may
        # preempt later-admitted members of this same round
        writable: List[Request] = []
        for req in active:
            if req.state is not RequestState.RUNNING:
                continue  # preempted by an earlier reservation
            self.scheduler.reserve_decode_slot(req)
            writable.append(req)
        writable = [r for r in writable if r.state is RequestState.RUNNING]
        if not writable:
            return 0

        width = self.round_width
        tw = self._table_width()
        tokens = np.zeros((width, 1), np.int32)
        tables = np.zeros((width, tw), np.int32)   # garbage page 0 padding
        lengths = np.zeros((width,), np.int32)
        for i, req in enumerate(writable):
            tokens[i, 0] = req.generated[-1]
            tables[i] = self.pager.padded_table(req.rid, tw)
            # pager length already counts the reserved slot; the model wants
            # the pre-write count (the new row's position)
            lengths[i] = self.pager.length(req.rid) - 1

        decode = self._decode(tw)
        t0 = time.perf_counter()
        nxt, self.k_pools, self.v_pools = decode(
            self.params, self.k_pools, self.v_pools,
            jnp.asarray(tokens), jnp.asarray(tables), jnp.asarray(lengths))
        nxt = np.asarray(jax.block_until_ready(nxt))
        dt = time.perf_counter() - t0
        self.decode_s += dt
        self.rounds += 1

        # always-on transfer telemetry (ISSUE-6): every decode round feeds
        # the same (machine, kernel) store the paged kernel's pipeline does —
        # wall clock over the KV page-tiles this round actually attended
        if self._decode_fresh:
            self._decode_fresh = False  # round paid jit compile; don't record
        else:
            tiles = sum(self.pager.blocks_for(int(n) + 1)
                        for n in (lengths[i] for i in range(len(writable))))
            if autotune.telemetry_enabled() and tiles:
                autotune.record_transfer("paged_decode", dt / tiles)

        for i, req in enumerate(writable):
            req.kv_len = self.pager.length(req.rid)
            self._emit(req, int(nxt[i]))
            self.token_latencies_s.append(dt)
            if req.done:
                self.scheduler.finish(req)
                self.finished.append(req)
                if self.on_finish:
                    self.on_finish(req)
        return len(writable)

    # ----------------------------------------------------------------- run

    def run(self, max_rounds: int = 100_000) -> Dict[str, Any]:
        """Serve until every submitted request finishes. Returns stats."""
        rounds = 0
        while self.scheduler.has_work():
            if rounds >= max_rounds:
                raise RuntimeError(f"no convergence in {max_rounds} rounds")
            self.step_round()
            rounds += 1
        self.pager.check_invariants()
        return self.stats()

    def stats(self) -> Dict[str, Any]:
        decoded = len(self.token_latencies_s)
        agg_kv = sum(len(r.prompt) + len(r.generated) for r in self.finished)
        pool_tokens = self.pager.pool_tokens
        out = {
            "engine": "paged",
            "machine": get_machine().name,
            "requests": len(self._requests),
            "completed": len(self.finished),
            "rounds": self.rounds,
            "preemptions": self.scheduler.preemptions,
            "round_width": self.round_width,
            "solved_depth": self.solved_depth,
            "block_size": self.pager.block_size,
            "num_blocks": self.pager.num_blocks,
            "pool_tokens": pool_tokens,
            "aggregate_kv_tokens": agg_kv,
            "kv_oversubscription": round(agg_kv / max(pool_tokens, 1), 2),
            "prefill_s": round(self.prefill_s, 3),
            "decode_s": round(self.decode_s, 3),
            "decode_tok_per_s": round(decoded / max(self.decode_s, 1e-9), 1),
        }
        out.update(latency_report(self.token_latencies_s))
        if self.finished:
            out["sample_tokens"] = self.finished[0].generated[:8]
        return out

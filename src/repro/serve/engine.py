"""Paged-KV continuous-batching serving engine on the coroutine substrate.

Every round the scheduler plans work under a token budget: all running
requests decode one token through a single jitted
`models.lm.decode_step_paged` (per-request ragged positions, one fixed round
width, pools donated so the cache updates in place), and the leftover budget
drives **chunked prefill** — admitted prompts trickle through
`models.lm.prefill_chunk_paged` a fixed-size chunk at a time, writing KV
directly into their pages instead of the old dense-prefill-then-scatter.
The round width is the pipeline depth `core.autotune` solves for the paged
decode `CoroSpec`: the scheduler keeps as many request-coroutines in flight
as the tuned pipeline keeps page-tiles in flight.

Shared prompt prefixes dedup through the radix **prefix cache**
(`serve/prefix_cache.py`): admission looks the prompt up, already-resident
pages are refcounted into the new request's table, and only the suffix is
prefilled. Pages a request would write mid-block are copy-on-write forked
first (`KVPager.ensure_writable` + a physical page copy here). Under pool
pressure the engine reclaims least-recently-hit cache-only pages before the
scheduler resorts to preemption.

The decode math runs through the jnp twin (`models.common`), which jits on
any backend; `kernels/decode_attention.paged_flash_decode` is the TPU
pipeline the round rides there (validated for parity in
tests/test_serve_paged.py, benchmarked in benchmarks/kernel_bench.py).

Because freed pages are reused immediately, the aggregate KV served over a
workload routinely exceeds what the same HBM held as a dense
``[batch, max_len]`` cache — `stats()["kv_oversubscription"]` reports the
ratio.

Failure model (ISSUE-9, DESIGN.md §2.6): every submitted request reaches a
terminal state — FINISHED, CANCELLED, or FAILED — no matter what the pool,
the steps, or the injected chaos (`serve/faults.py`) do. Per-request
deadlines (`deadline_s`) cancel expired requests at round boundaries;
`cancel(rid)` does the same on demand; a bounded admission queue
(`max_queue`) sheds overflow at submit time (FAILED, reason "shed").
`step_round` is exception-safe: a step that raises marks its requests
faulted and retries them, quarantining any request whose consecutive-fault
count exceeds `max_request_faults` (pages freed, trace span closed,
`on_finish` invoked, state FAILED); unresolvable pool pressure
(`PoolExhausted` escaping reclaim + preemption) requeues the request at
the head of the waiting line and quarantines it after `max_stalls`
attempts. `run()` never raises on a wedged workload: after `max_rounds`
total or `max_idle_rounds` rounds of zero progress it cancels the
remainder (reason "stalled") and returns partial stats with full
stalled/failed/shed/deadline accounting.

Observability (ISSUE-8, DESIGN.md §2.5): every engine instance owns one
`obs.metrics` registry — the prefix/COW counters and the token-latency /
TTFT / TBT histograms live there, and `stats()` is a read-time view over
it, not a parallel dict. The engine also feeds the process tracer
(`obs.trace`): per-round / decode-round / prefill-chunk spans, an async
``request`` span per request lifetime, a ``pipeline:paged_decode`` span per
decode round (depth / n_tiles / context-bytes attributes), and instant
events for COW forks and cache evictions (preemptions are emitted by the
scheduler). Both degrade to module-level null objects under
``REPRO_TELEMETRY=0`` — no per-call branching in the round loop.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, FrozenSet, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import autotune, guard
from repro.core.machine import get_machine
from repro.kernels.decode_attention.decode_attention import paged_decode_spec
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import latency_report  # noqa: F401  (re-export; the
#   one shared implementation lives in obs.metrics — ISSUE-8 satellite)
from repro.serve.faults import NULL_INJECTOR, FaultInjector
from repro.serve.kv_pager import KVPager, PoolExhausted
from repro.serve.prefill import ChunkedPrefiller
from repro.serve.prefix_cache import MISS, PrefixCache, PrefixMatch
from repro.serve.scheduler import (
    ContinuousBatchingScheduler,
    Request,
    RequestState,
)
from repro.sharding import NULL_CTX, ShardingCtx


class PagedServingEngine:
    """Continuous batching over a paged KV pool for one model instance."""

    def __init__(self, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX, *,
                 block_size: int = 16, num_blocks: int = 64,
                 max_in_flight: Optional[int] = None,
                 prefix_cache: bool = True,
                 prefill_chunk: int = 32,
                 token_budget: Optional[int] = None,
                 params: Optional[Any] = None, seed: int = 0,
                 on_token: Optional[Callable[[Request, int], None]] = None,
                 on_finish: Optional[Callable[[Request], None]] = None,
                 deadline_s: Optional[float] = None,
                 max_queue: Optional[int] = None,
                 max_stalls: int = 8,
                 max_request_faults: int = 3,
                 faults: Optional[FaultInjector] = None):
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.cfg = cfg
        self.ctx = ctx
        self.model = build_model(cfg, ctx)
        if not self.model.supports_paged_decode():
            raise ValueError(
                f"arch {cfg.name!r} (family={cfg.family}, sliding_window="
                f"{cfg.sliding_window}) needs the dense/ring/recurrent cache "
                "path; the paged engine serves plain-attention archs")
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed)))
        self.faults = faults if faults is not None else NULL_INJECTOR
        self.deadline_s = deadline_s
        self.max_queue = max_queue
        self.max_stalls = int(max_stalls)
        self.max_request_faults = int(max_request_faults)
        self.pager = KVPager(num_blocks, block_size, faults=self.faults)
        self.prefix_cache: Optional[PrefixCache] = (
            PrefixCache(self.pager) if prefix_cache else None)
        kh, hd, g = cfg.kv_heads, cfg.resolved_head_dim, cfg.n_heads // cfg.kv_heads

        # scheduler <-> autotune coupling: in-flight requests per round =
        # the solved pipeline depth of the paged decode spec (clamped to 2+)
        spec = paged_decode_spec(block_size, kh, g, hd, jnp.dtype(cfg.dtype),
                                 max_blocks=max(num_blocks, 1))
        self.solved_depth = autotune.choose_depth(
            spec.profile(), kernel="paged_decode", vars=spec.all_vars())
        self._pipeline_ctx_bytes = spec.context_bytes(self.solved_depth)
        # a round can't usefully exceed one block-owning request per block
        self.round_width = int(max_in_flight
                               or min(max(2, self.solved_depth), num_blocks))
        self.prefill_chunk = int(prefill_chunk)
        # budget: every running request decodes, plus one chunk's worth of
        # prefill trickles alongside — decode is never starved, prefill
        # never stalls a round for a whole prompt
        self.token_budget = int(token_budget
                                or self.round_width + self.prefill_chunk)
        self.scheduler = ContinuousBatchingScheduler(
            self.pager, self.round_width,
            token_budget=self.token_budget, reclaim=self._reclaim,
            faults=self.faults)
        self.prefiller = ChunkedPrefiller(self.model, block_size)

        shape = (cfg.n_layers, self.pager.physical_blocks, block_size, kh, hd)
        self.k_pools = jnp.zeros(shape, jnp.dtype(cfg.dtype))
        self.v_pools = jnp.zeros(shape, jnp.dtype(cfg.dtype))

        self.on_token = on_token
        self.on_finish = on_finish
        self._requests: Dict[int, Request] = {}   # live (non-terminal) only
        self._done: Dict[int, Request] = {}       # terminal, any reason
        self._next_rid = 0
        self._decode_fn = None                  # jit cache keyed by table width
        self._decode_fn_width = 0
        self._decode_fresh = False
        self._tw_hw = 1    # padded-table high-water mark (re-jit guard)
        self.rounds = 0
        self.finished: List[Request] = []       # FINISHED (completed) only

        # one registry per engine instance (two engines in one process must
        # not mix counters); `stats()` is a view over it — ISSUE-8. The
        # tracer is fetched once: the round loop calls through it with no
        # enabled() branching (null objects under REPRO_TELEMETRY=0).
        self.metrics = obs_metrics.new_registry()
        self.tracer = obs_trace.get_tracer()
        m = self.metrics
        self._c_prefix_hits = m.counter("serve.prefix_hits")
        self._c_prefix_tokens = m.counter("serve.prefix_tokens")
        self._c_blocks_shared = m.counter("serve.blocks_shared")
        self._c_cow_forks = m.counter("serve.cow_forks")
        self._c_prefill_s = m.counter("serve.prefill_s")
        self._c_decode_s = m.counter("serve.decode_s")
        self._h_token = m.histogram("serve.token_latency_s")
        self._h_tbt = m.histogram("serve.tbt_s")   # inter-token gaps
        self._h_ttft = m.histogram("serve.ttft_s")
        # failure-model counters (ISSUE-9): terminal accounting in stats()
        # is derived from the requests themselves (robust under
        # REPRO_TELEMETRY=0); these feed the scrapeable registry
        self._c_cancelled = m.counter("serve.cancelled")
        self._c_failed = m.counter("serve.failed")
        self._c_shed = m.counter("serve.shed")
        self._c_deadline = m.counter("serve.deadline_expired")
        self._c_stalls = m.counter("serve.stalls")
        self._c_step_faults = m.counter("serve.step_faults")

    # ------------------------------------------------- registry views
    #
    # read-only aliases of the registry metrics, kept so callers (tests,
    # notebooks) that peeked at the old plain attributes still work

    @property
    def prefix_hits(self) -> int:
        return int(self._c_prefix_hits.value)

    @property
    def prefix_tokens(self) -> int:
        return int(self._c_prefix_tokens.value)

    @property
    def blocks_shared(self) -> int:
        return int(self._c_blocks_shared.value)

    @property
    def cow_forks(self) -> int:
        return int(self._c_cow_forks.value)

    @property
    def prefill_s(self) -> float:
        return self._c_prefill_s.value

    @property
    def decode_s(self) -> float:
        return self._c_decode_s.value

    @property
    def token_latencies_s(self) -> List[float]:
        return self._h_token.samples

    @property
    def tbt_s(self) -> List[float]:
        return self._h_tbt.samples

    # -------------------------------------------------------------- intake

    def submit(self, prompt_tokens, max_new_tokens: int,
               deadline_s: Optional[float] = None) -> int:
        """Queue one request. Returns its id; results stream via callbacks
        and land on `request(rid).generated`.

        `deadline_s` (relative seconds; the engine default applies when
        None) bounds the request's wall-clock lifetime — past it, the
        request is CANCELLED at the next round boundary. When the waiting
        queue is full (`max_queue`), the request is **shed**: it still gets
        an id, but it is immediately terminal (FAILED, reason "shed") and
        `on_finish` fires — the caller distinguishes by state, not by
        exception, so a bursty client never crashes the intake path."""
        prompt = [int(t) for t in np.asarray(prompt_tokens).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        max_total = len(prompt) + max_new_tokens
        if self.pager.blocks_for(max_total) > self.pager.num_blocks:
            raise ValueError(
                f"request needs {self.pager.blocks_for(max_total)} blocks at "
                f"full length; pool has {self.pager.num_blocks}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=int(max_new_tokens))
        req.submit_s = time.perf_counter()
        rel = deadline_s if deadline_s is not None else self.deadline_s
        if rel is not None:
            req.deadline_s = req.submit_s + float(rel)
        self._requests[rid] = req
        self.tracer.begin_async("request", rid,
                                tid=obs_trace.TID_REQUEST_BASE + rid,
                                prompt_len=len(prompt),
                                max_new_tokens=int(max_new_tokens))
        if (self.max_queue is not None
                and len(self.scheduler.waiting) >= self.max_queue):
            self._c_shed.inc()
            self.tracer.instant("shed", rid=rid,
                                queued=len(self.scheduler.waiting))
            self._retire(req, RequestState.FAILED, "shed")
            return rid
        self.scheduler.submit(req)
        return rid

    def request(self, rid: int) -> Request:
        live = self._requests.get(rid)
        return live if live is not None else self._done[rid]

    def cancel(self, rid: int, *, reason: str = "cancelled") -> bool:
        """Cancel a live request: pages freed, span closed, `on_finish`
        invoked, terminal state CANCELLED. False if the id is unknown or
        the request is already terminal (cancel is idempotent)."""
        req = self._requests.get(rid)
        if req is None:
            return False
        self._c_cancelled.inc()
        if reason == "deadline":
            self._c_deadline.inc()
        self.tracer.instant("cancel", rid=rid, reason=reason,
                            state=req.state.value)
        self._retire(req, RequestState.CANCELLED, reason)
        return True

    # --------------------------------------------------- terminal plumbing

    def _retire(self, req: Request, state: RequestState, reason: str,
                error: Optional[str] = None) -> None:
        """The one terminal transition every non-complete path routes
        through: dequeue + free pages, stamp the reason, close the request
        trace span, move the request to the retired map, fire
        `on_finish`. Idempotent — a request already terminal is left be."""
        if req.rid in self._done:
            return
        self.scheduler.retire(req, state)
        req.finish_reason = reason
        if error is not None:
            req.error = error
        self._requests.pop(req.rid, None)
        self._done[req.rid] = req
        self.tracer.end_async("request", req.rid,
                              tid=obs_trace.TID_REQUEST_BASE + req.rid,
                              generated=len(req.generated),
                              state=state.value, reason=reason)
        if self.on_finish:
            self.on_finish(req)

    def _quarantine(self, req: Request, err: BaseException, *,
                    reason: str = "fault") -> None:
        """Poisoned request: isolate it so the engine (and every other
        request) survives. Pages freed, span closed, `on_finish` fired."""
        self._c_failed.inc()
        self.tracer.instant("quarantine", rid=req.rid, reason=reason,
                            error=type(err).__name__)
        self._retire(req, RequestState.FAILED, reason,
                     error=f"{type(err).__name__}: {err}")

    def _note_fault(self, req: Request, err: BaseException) -> None:
        """A step serving `req` raised. Transient faults retry (the request
        stays where it is); `max_request_faults` consecutive failures
        quarantine it. The counter resets on any successful step."""
        req.fault_count += 1
        self._c_step_faults.inc()
        self.tracer.instant("step_fault", rid=req.rid,
                            count=req.fault_count,
                            error=type(err).__name__)
        if req.fault_count > self.max_request_faults:
            self._quarantine(req, err)

    def _stall(self, req: Request, err: BaseException) -> None:
        """Pool pressure that reclaim + preemption could not resolve for
        `req`: requeue it (recompute-on-readmit) and count the stall;
        `max_stalls` of them quarantine it as unservable right now."""
        req.stalls += 1
        self._c_stalls.inc()
        self.tracer.instant("stall", rid=req.rid, stalls=req.stalls)
        if req.stalls > self.max_stalls:
            self._quarantine(req, err, reason="pool_exhausted")
        else:
            self.scheduler.requeue(req)

    def _expire_deadlines(self) -> None:
        now = time.perf_counter()
        expired = [r for r in self._requests.values()
                   if r.deadline_s is not None and now >= r.deadline_s]
        for req in expired:
            self.cancel(req.rid, reason="deadline")

    # ------------------------------------------------------ prefix plumbing

    @property
    def _prefill_fns(self) -> Dict[Any, Any]:
        """The chunk-step jit cache — bounded by pow2 length bucketing
        (tests assert its size stays logarithmic in prompt length)."""
        return self.prefiller._fns

    def _match(self, tokens) -> PrefixMatch:
        if self.prefix_cache is None:
            return MISS
        with self.tracer.span("prefix_lookup", n_tokens=len(tokens)):
            return self.prefix_cache.match(tokens)

    def _reclaim(self, n_blocks: int, protect: FrozenSet[int]) -> int:
        """Scheduler pressure hook: drop LRU cache-only pages."""
        if self.faults.fire("reclaim_refuse", requested=n_blocks):
            return 0  # injected: every cold page is pinned right now
        if self.prefix_cache is None:
            return 0
        freed = len(self.prefix_cache.evict(n_blocks, protect))
        if freed:
            self.tracer.instant("cache_evict", requested=n_blocks,
                                freed=freed)
        return freed

    def _copy_page(self, src: int, dst: int) -> None:
        """Materialise a copy-on-write fork in the physical pools."""
        self.k_pools = self.k_pools.at[:, dst].set(self.k_pools[:, src])
        self.v_pools = self.v_pools.at[:, dst].set(self.v_pools[:, src])
        self._c_cow_forks.inc()
        self.tracer.instant("cow_fork", src=src, dst=dst)

    def _make_writable(self, req: Request, pos: int) -> None:
        copy = self.scheduler.make_writable(req, pos)
        if copy is not None:
            self._copy_page(*copy)

    # ------------------------------------------------------------- prefill

    def _prefill_chunk_step(self, req: Request, n: int) -> None:
        """Run one `n`-token chunk of `req`'s prefill; on the last chunk,
        emit the first generated token and promote (or finish)."""
        ctxt = req.context
        start = req.prefill_pos
        n = min(n, len(ctxt) - start)
        if n <= 0:
            return
        self.faults.check("prefill", rid=req.rid, start=start, n=n)
        guard.check_injected("paged_prefill_chunk", self.faults,
                             rid=req.rid, start=start, n=n)
        # the chunk's first page may be shared (a partial-block prefix hit):
        # fork it before writing rows into it
        self._make_writable(req, start)
        if req.state is not RequestState.PREFILL:
            return  # the fork's pressure resolution preempted this request
        tw = self._table_width()
        table = self.pager.padded_table(req.rid, tw)
        t0 = time.perf_counter()
        with self.tracer.span("prefill_chunk", rid=req.rid, start=start, n=n):
            logits, self.k_pools, self.v_pools, _ = self.prefiller.run_chunk(
                self.params, self.k_pools, self.v_pools,
                ctxt[start:start + n], table, start, n)
        self._c_prefill_s.inc(time.perf_counter() - t0)
        # the always-on numerics scan (DESIGN.md §2.7): a non-finite chunk
        # raises before prefill_pos advances, so the chunk re-runs on retry
        # (KV rows rewrite idempotently; the pools are already committed)
        nerr = guard.scan_output("paged_prefill_chunk", logits)
        if nerr is not None:
            raise nerr
        req.prefill_pos = start + n
        if self.prefix_cache is not None:
            self.prefix_cache.insert(ctxt[:req.prefill_pos],
                                     self.pager.block_table(req.rid))
        if req.prefill_pos >= len(ctxt):
            first = int(jnp.argmax(logits[0]))
            self._emit(req, first)
            if req.done:  # max_new_tokens == 1: satisfied by this token
                self._finish(req)
            else:
                self.scheduler.promote(req)

    def _emit(self, req: Request, token: int) -> None:
        now = time.perf_counter()
        if req.first_token_s is None:
            req.first_token_s = now
        elif req.last_emit_s is not None:
            self._h_tbt.observe(now - req.last_emit_s)
        req.last_emit_s = now
        req.generated.append(token)
        if self.on_token:
            self.on_token(req, token)

    def _finish(self, req: Request) -> None:
        """Retire one completed request: free its pages, close its
        lifecycle span, and fold its TTFT into the registry histogram."""
        if req.rid in self._done:
            return  # a callback already cancelled it mid-step
        self.scheduler.finish(req)
        req.finish_reason = "complete"
        self.finished.append(req)
        self._requests.pop(req.rid, None)
        self._done[req.rid] = req
        if req.ttft_s is not None:
            self._h_ttft.observe(req.ttft_s)
        self.tracer.end_async("request", req.rid,
                              tid=obs_trace.TID_REQUEST_BASE + req.rid,
                              generated=len(req.generated),
                              preemptions=req.preemptions)
        if self.on_finish:
            self.on_finish(req)

    # -------------------------------------------------------------- decode

    def _decode(self, table_width: int):
        if self._decode_fn is None or table_width != self._decode_fn_width:
            model = self.model

            def step(params, k_pools, v_pools, tokens, tables, lengths):
                logits, k_pools, v_pools = model.decode_step_paged(
                    params, k_pools, v_pools, tables, lengths,
                    {"tokens": tokens})
                return jnp.argmax(logits[:, -1], axis=-1), k_pools, v_pools

            self._decode_fn = jax.jit(step, donate_argnums=(1, 2))
            self._decode_fn_width = table_width
            # the next round's wall clock includes jit compile: keep it out
            # of the transfer-telemetry feedback store
            self._decode_fresh = True
        return self._decode_fn

    def _table_width(self) -> int:
        """Block-table width: every request's table padded to the worst
        case any **live** request can reach, tracked as a high-water mark
        so the decode jit key is stable across the rounds of one workload.
        Terminal requests move out of `_requests`, so one long retired
        request no longer pins the width (and the per-round staging
        arrays) forever; the mark only drops once the live need falls to
        half of it — a single short-lived dip never thrashes the jit."""
        need = max((self.pager.blocks_for(len(r.prompt) + r.max_new_tokens)
                    for r in self._requests.values()), default=1)
        need = max(need, 1)
        if need > self._tw_hw:
            self._tw_hw = need
        elif need <= self._tw_hw // 2:
            self._tw_hw = need
        return self._tw_hw

    def _decode_round(self, active: List[Request]) -> int:
        """Decode one token for every (still-)running request in `active`."""
        # reserve pool room for each request's next token; reserving may
        # preempt later-admitted members of this same round, and writing
        # mid-block may copy-on-write fork a page the prefix cache shares.
        # Pressure neither reclaim nor preemption can resolve stalls the
        # request (requeue, bounded retries) instead of crashing the round.
        writable: List[Request] = []
        for req in active:
            if req.state is not RequestState.RUNNING:
                continue  # preempted by an earlier reservation
            try:
                pos = self.scheduler.reserve_decode_slot(req)
                if req.state is RequestState.RUNNING:
                    self._make_writable(req, pos)
            except PoolExhausted as e:
                self._stall(req, e)
                continue
            writable.append(req)
        writable = [r for r in writable if r.state is RequestState.RUNNING]
        if not writable:
            return 0

        width = self.round_width
        tw = self._table_width()
        tokens = np.zeros((width, 1), np.int32)
        tables = np.zeros((width, tw), np.int32)   # garbage page 0 padding
        lengths = np.zeros((width,), np.int32)
        for i, req in enumerate(writable):
            tokens[i, 0] = req.generated[-1]
            tables[i] = self.pager.padded_table(req.rid, tw)
            # pager length already counts the reserved slot; the model wants
            # the pre-write count (the new row's position)
            lengths[i] = self.pager.length(req.rid) - 1

        t0 = time.perf_counter()
        try:
            self.faults.check("decode", round=self.rounds,
                              width=len(writable))
            # kernel-site faults fire BEFORE the jit call: the decode jit
            # donates the pools, so an attempt must not consume them and
            # then fail — a typed SubstrateError here rides the same
            # rollback + _note_fault path as any other step fault
            guard.check_injected("paged_decode_round", self.faults,
                                 round=self.rounds, width=len(writable))
            decode = self._decode(tw)
            with self.tracer.span("decode_round", width=len(writable),
                                  table_width=tw):
                nxt, self.k_pools, self.v_pools = decode(
                    self.params, self.k_pools, self.v_pools,
                    jnp.asarray(tokens), jnp.asarray(tables),
                    jnp.asarray(lengths))
                nxt = np.asarray(jax.block_until_ready(nxt))
        except Exception as e:
            # the batched step raised: no KV row was written, so roll the
            # reservations back and let every member retry next round —
            # attribution inside a batch is ambiguous, so blame is shared
            # and `max_request_faults` consecutive failures quarantine
            for req in writable:
                self.scheduler.unreserve(req)
            for req in writable:
                self._note_fault(req, e)
            return 0
        dt = time.perf_counter() - t0
        self._c_decode_s.inc(dt)

        # always-on transfer telemetry (ISSUE-6): every decode round feeds
        # the same (machine, kernel) store the paged kernel's pipeline does —
        # wall clock over the KV page-tiles this round actually attended.
        # The same interval is the round's `pipeline:paged_decode` span on
        # the tracer (ISSUE-8), depth / n_tiles / context-bytes attributes
        # matching what coro_call stamps on a real kernel launch.
        tiles = sum(self.pager.blocks_for(int(n) + 1)
                    for n in (lengths[i] for i in range(len(writable))))
        end_us = self.tracer.now_us()
        self.tracer.complete("pipeline:paged_decode", end_us - dt * 1e6,
                             dt * 1e6, tid=obs_trace.TID_KERNEL,
                             depth=self.solved_depth, n_tiles=tiles,
                             context_bytes=self._pipeline_ctx_bytes,
                             jit_warmup=self._decode_fresh)
        if self._decode_fresh:
            self._decode_fresh = False  # round paid jit compile; don't record
        elif autotune.telemetry_enabled() and tiles:
            autotune.record_transfer("paged_decode", dt / tiles)

        for i, req in enumerate(writable):
            if req.state is not RequestState.RUNNING:
                continue  # a callback cancelled it mid-round
            req.fault_count = 0  # a successful step clears shared blame
            req.kv_len = self.pager.length(req.rid)
            self._emit(req, int(nxt[i]))
            self._h_token.observe(dt)
            if req.done:
                self._finish(req)
        return len(writable)

    # --------------------------------------------------------------- round

    def step_round(self) -> int:
        """One budgeted scheduler round: expire deadlines, admit (with
        prefix lookup), decode one token for every running request, then
        spend the leftover budget on prefill chunks. Exception-safe: a
        failing step faults (and eventually quarantines) the requests it
        served, never the engine. Returns tokens emitted this round."""
        with self.tracer.span("round", n=self.rounds):
            self._expire_deadlines()
            spike = self.faults.latency_spike("latency")
            if spike > 0.0:
                self.tracer.instant("latency_spike",
                                    sleep_ms=round(spike * 1e3, 3))
                time.sleep(spike)
            for req in self.scheduler.admit(match=self._match):
                self.tracer.instant("admit", rid=req.rid,
                                    matched=req.matched_len,
                                    context=len(req.context))
                if req.matched_len > 0:
                    self._c_prefix_hits.inc()
                    self._c_prefix_tokens.inc(req.matched_len)
                    self._c_blocks_shared.inc(
                        self.pager.blocks_for(req.matched_len))

            decodes, plans = self.scheduler.plan_round(self.prefill_chunk)
            emitted = self._decode_round(decodes)
            for req, n in plans:
                if req.state is not RequestState.PREFILL:
                    continue  # preempted resolving an earlier req's pressure
                before = len(req.generated)
                try:
                    self._prefill_chunk_step(req, n)
                    req.fault_count = 0
                except PoolExhausted as e:
                    self._stall(req, e)
                except Exception as e:
                    self._note_fault(req, e)
                emitted += len(req.generated) - before
            self.rounds += 1
            return emitted

    # ----------------------------------------------------------------- run

    def run(self, max_rounds: int = 100_000, *,
            max_idle_rounds: int = 64) -> Dict[str, Any]:
        """Serve until every submitted request reaches a terminal state.

        Never raises on a wedged workload: past `max_rounds` total — or
        `max_idle_rounds` consecutive rounds with nothing in flight and
        nothing admitted (a head request the pool can never hold) — the
        remaining requests are cancelled (reason "stalled") and the stats
        of the work that *did* complete are returned, with the stall/fail
        accounting alongside."""
        rounds = 0
        idle = 0
        while self.scheduler.has_work():
            if rounds >= max_rounds or idle >= max_idle_rounds:
                for req in list(self._requests.values()):
                    self.cancel(req.rid, reason="stalled")
                self.tracer.instant("run_stalled", rounds=rounds, idle=idle)
                break
            emitted = self.step_round()
            rounds += 1
            if emitted == 0 and self.scheduler.in_flight() == 0:
                idle += 1
            else:
                idle = 0
        self.pager.check_invariants(
            self.prefix_cache.block_refs() if self.prefix_cache else None)
        return self.stats()

    def stats(self) -> Dict[str, Any]:
        """Aggregate serving stats — a read-time VIEW over the engine's
        metrics registry (plus pager/scheduler state), not a parallel
        store. `metrics.snapshot()` / `metrics.prometheus_text()` expose
        the same registry for scraping."""
        decoded = self._h_token.count
        agg_kv = sum(len(r.prompt) + len(r.generated) for r in self.finished)
        pool_tokens = self.pager.pool_tokens
        # terminal accounting straight off the retired requests themselves:
        # correct even with the metrics registry nulled (REPRO_TELEMETRY=0)
        by_state: Dict[RequestState, int] = {}
        by_reason: Dict[str, int] = {}
        for r in self._done.values():
            by_state[r.state] = by_state.get(r.state, 0) + 1
            by_reason[r.finish_reason] = by_reason.get(r.finish_reason, 0) + 1
        out = {
            "engine": "paged",
            "machine": get_machine().name,
            "requests": self._next_rid,
            "live": len(self._requests),
            "completed": len(self.finished),
            "cancelled": by_state.get(RequestState.CANCELLED, 0),
            "failed": by_state.get(RequestState.FAILED, 0),
            "shed": by_reason.get("shed", 0),
            "deadline_expired": by_reason.get("deadline", 0),
            "stalled": by_reason.get("stalled", 0),
            "stalls": int(self._c_stalls.value),
            "step_faults": int(self._c_step_faults.value),
            "faults_injected": self.faults.injected,
            "substrate": guard.stats(),  # process-wide guarded-call totals
            "rounds": self.rounds,
            "preemptions": self.scheduler.preemptions,
            "round_width": self.round_width,
            "solved_depth": self.solved_depth,
            "token_budget": self.token_budget,
            "prefill_chunk": self.prefill_chunk,
            "prefill_chunks": self.prefiller.chunks_run,
            "block_size": self.pager.block_size,
            "num_blocks": self.pager.num_blocks,
            "pool_tokens": pool_tokens,
            "blocks_allocated": self.pager.blocks_allocated,
            "aggregate_kv_tokens": agg_kv,
            "kv_oversubscription": round(agg_kv / max(pool_tokens, 1), 2),
            "prefix_cache": self.prefix_cache is not None,
            "prefix_hits": self.prefix_hits,
            "prefix_tokens": self.prefix_tokens,
            "blocks_shared": self.blocks_shared,
            "cow_forks": self.cow_forks,
            "cache_blocks": (len(self.prefix_cache)
                             if self.prefix_cache else 0),
            "cache_evictions": (self.prefix_cache.evictions
                                if self.prefix_cache else 0),
            "prefill_s": round(self.prefill_s, 3),
            "decode_s": round(self.decode_s, 3),
            "decode_tok_per_s": round(decoded / max(self.decode_s, 1e-9), 1),
            "ttft_p50_ms": latency_report(self._h_ttft.samples)["p50_ms"],
            "ttft_p99_ms": latency_report(self._h_ttft.samples)["p99_ms"],
            "tbt_p50_ms": latency_report(self._h_tbt.samples)["p50_ms"],
            "tbt_p99_ms": latency_report(self._h_tbt.samples)["p99_ms"],
        }
        out.update(latency_report(self._h_token.samples))
        if self.finished:
            out["sample_tokens"] = self.finished[0].generated[:8]
        return out

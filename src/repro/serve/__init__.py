"""Paged-KV continuous-batching serving on the coroutine substrate.

  kv_pager     - HBM block pool + refcounted tables, copy-on-write forks
  prefix_cache - radix index: shared prompt prefixes -> shared KV pages
  prefill      - chunked prefill through the paged pipeline (pow2 jit cache)
  scheduler    - admit/evict/preempt; budgeted rounds mixing decode + chunks
  engine       - the serving loop wiring them together, streaming completions
"""
from repro.serve.engine import PagedServingEngine, latency_report
from repro.serve.kv_pager import GARBAGE_BLOCK, KVPager, PoolExhausted
from repro.serve.prefill import ChunkedPrefiller, bucket_len
from repro.serve.prefix_cache import MISS, PrefixCache, PrefixMatch
from repro.serve.scheduler import (
    ContinuousBatchingScheduler,
    Request,
    RequestState,
)

__all__ = [
    "ChunkedPrefiller",
    "ContinuousBatchingScheduler",
    "GARBAGE_BLOCK",
    "KVPager",
    "MISS",
    "PagedServingEngine",
    "PoolExhausted",
    "PrefixCache",
    "PrefixMatch",
    "Request",
    "RequestState",
    "bucket_len",
    "latency_report",
]

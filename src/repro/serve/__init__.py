"""Paged-KV continuous-batching serving on the coroutine substrate.

  kv_pager    - HBM block pool + per-request block tables (host bookkeeping)
  scheduler   - admit/evict/preempt; rounds bounded by the autotuned depth
  engine      - prefill-then-decode loop with streaming completions
"""
from repro.serve.engine import PagedServingEngine, latency_report
from repro.serve.kv_pager import GARBAGE_BLOCK, KVPager, PoolExhausted
from repro.serve.scheduler import (
    ContinuousBatchingScheduler,
    Request,
    RequestState,
)

__all__ = [
    "ContinuousBatchingScheduler",
    "GARBAGE_BLOCK",
    "KVPager",
    "PagedServingEngine",
    "PoolExhausted",
    "Request",
    "RequestState",
    "latency_report",
]

"""Paged-KV continuous-batching serving on the coroutine substrate.

  kv_pager     - HBM block pool + refcounted tables, copy-on-write forks
  prefix_cache - radix index: shared prompt prefixes -> shared KV pages
  prefill      - chunked prefill through the paged pipeline (pow2 jit cache)
  scheduler    - admit/evict/preempt; budgeted rounds mixing decode + chunks
  faults       - seeded deterministic fault injection (chaos schedules)
  engine       - the serving loop wiring them together, streaming completions
"""
from repro.serve.engine import PagedServingEngine, latency_report
from repro.serve.faults import NULL_INJECTOR, FaultInjector, InjectedFault
from repro.serve.kv_pager import GARBAGE_BLOCK, KVPager, PoolExhausted
from repro.serve.prefill import ChunkedPrefiller, bucket_len
from repro.serve.prefix_cache import MISS, PrefixCache, PrefixMatch
from repro.serve.scheduler import (
    TERMINAL_STATES,
    ContinuousBatchingScheduler,
    Request,
    RequestState,
)

__all__ = [
    "ChunkedPrefiller",
    "ContinuousBatchingScheduler",
    "FaultInjector",
    "GARBAGE_BLOCK",
    "InjectedFault",
    "KVPager",
    "MISS",
    "NULL_INJECTOR",
    "PagedServingEngine",
    "PoolExhausted",
    "PrefixCache",
    "PrefixMatch",
    "Request",
    "RequestState",
    "TERMINAL_STATES",
    "bucket_len",
    "latency_report",
]

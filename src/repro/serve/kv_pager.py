"""Paged KV-cache block manager: fixed-size HBM pages, per-request tables,
refcounted sharing with copy-on-write.

The serving-side analogue of the paper's far-memory arena: the KV cache is
not a dense ``[batch, max_len]`` allocation but a pool of fixed-size blocks
("pages") in HBM, and each request owns a *block table* — the list of pages
its logical positions map onto. Pages are the coroutine tiles of the paged
decode kernel (`kernels/decode_attention.paged_flash_decode`): the pipeline
fetches them through the table, so physical placement is free and freed
pages are reused immediately (defrag-free by construction — no page ever
needs to move).

Since the prefix-cache subsystem (ISSUE-7) pages are **refcounted**: a page
may be referenced by several request tables at once (a shared prompt
prefix) and/or by the radix prefix index (`serve/prefix_cache.py`). `free`
only returns a page to the free list when its last reference drops;
`ensure_writable` implements copy-on-write — before a request writes a KV
row into a shared page, the page is forked (a fresh page replaces it in
that request's table and the caller copies the contents).

This module is pure host-side bookkeeping (no jax): the engine owns the
actual pool arrays and indexes them with the tables produced here.

Layout convention (shared with models.lm / the kernel): block id 0 is a
reserved *garbage* page that is never allocated. Round padding slots point
every table entry at it, so their masked-out scatters/gathers land somewhere
harmless. A pool advertising `num_blocks` usable pages is therefore
physically `num_blocks + 1` blocks (`KVPager.physical_blocks`).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.faults import NULL_INJECTOR

GARBAGE_BLOCK = 0


class PoolExhausted(RuntimeError):
    """No free block available (caller should evict, preempt or wait)."""


class KVPager:
    """Block pool allocator: alloc/append/share/fork/free with leak-proof
    refcounted accounting."""

    def __init__(self, num_blocks: int, block_size: int, *,
                 faults=NULL_INJECTOR):
        if num_blocks < 1 or block_size < 1:
            raise ValueError(f"need >=1 blocks of >=1 tokens, got "
                             f"{num_blocks}x{block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.faults = faults  # serve.faults hook ("pool_exhausted" site)
        # block ids 1..num_blocks; 0 is the reserved garbage page
        self._free = deque(range(1, self.num_blocks + 1))
        self._refcounts: Dict[int, int] = {}
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}
        self.blocks_allocated = 0  # cumulative free-list pops (cold + forks)

    # ------------------------------------------------------------- queries

    @property
    def physical_blocks(self) -> int:
        """Blocks the engine must allocate (usable pool + garbage page 0)."""
        return self.num_blocks + 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def pool_tokens(self) -> int:
        """Token capacity of the usable pool."""
        return self.num_blocks * self.block_size

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.block_size)

    def can_alloc(self, n_tokens: int, *, shared: int = 0) -> bool:
        """Can `n_tokens` be stored given `shared` already-resident prefix
        blocks (which cost no free-list pops)?"""
        return self.blocks_for(n_tokens) - shared <= self.free_blocks

    def owns(self, rid: int) -> bool:
        return rid in self._tables

    def length(self, rid: int) -> int:
        return self._lengths[rid]

    def block_table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def refcount(self, block: int) -> int:
        """References on an allocated block (owners + external/cache refs)."""
        return self._refcounts.get(block, 0)

    def padded_table(self, rid: int, max_blocks: int) -> np.ndarray:
        """Block table padded with the garbage page to a fixed width."""
        t = self._tables[rid]
        if len(t) > max_blocks:
            raise ValueError(f"request {rid} uses {len(t)} blocks > "
                             f"table width {max_blocks}")
        out = np.full((max_blocks,), GARBAGE_BLOCK, np.int32)
        out[: len(t)] = t
        return out

    # ----------------------------------------------------------- lifecycle

    def _pop_free(self) -> int:
        if self.faults.fire("pool_exhausted", free=len(self._free)):
            raise PoolExhausted("injected fault: pool_exhausted")
        if not self._free:
            raise PoolExhausted("no free block in the pool")
        b = self._free.popleft()
        self._refcounts[b] = 1
        self.blocks_allocated += 1
        return b

    def alloc(self, rid: int, n_tokens: int, *,
              prefix_blocks: Sequence[int] = (),
              prefix_len: int = 0) -> List[int]:
        """Claim blocks for `n_tokens` stored tokens. Returns the request's
        block table; raises `PoolExhausted` leaving state intact.

        `prefix_blocks` are already-allocated shared pages (a prefix-cache
        hit) covering the first `prefix_len` tokens — the last one may be
        only partially valid. They are refcounted into the table instead of
        popping fresh pages; only the suffix costs free blocks.
        """
        if rid in self._tables:
            raise ValueError(f"request {rid} already has an allocation")
        prefix_blocks = list(prefix_blocks)
        if self.blocks_for(prefix_len) != len(prefix_blocks):
            raise ValueError(
                f"prefix_len {prefix_len} needs {self.blocks_for(prefix_len)}"
                f" blocks, got {len(prefix_blocks)}")
        if prefix_len >= n_tokens and n_tokens > 0 and prefix_len > 0:
            raise ValueError(
                f"prefix_len {prefix_len} must leave >=1 token to prefill "
                f"(n_tokens={n_tokens})")
        for b in prefix_blocks:
            if self._refcounts.get(b, 0) < 1:
                raise ValueError(f"prefix block {b} is not allocated")
        need = self.blocks_for(n_tokens)
        fresh = need - len(prefix_blocks)
        if fresh < 0:
            raise ValueError(f"{len(prefix_blocks)} prefix blocks exceed the "
                             f"{need} blocks {n_tokens} tokens need")
        if fresh > self.free_blocks:
            raise PoolExhausted(
                f"request {rid}: need {fresh} fresh blocks, "
                f"{self.free_blocks} free")
        for b in prefix_blocks:
            self._refcounts[b] += 1
        popped: List[int] = []
        try:
            for _ in range(fresh):
                popped.append(self._pop_free())
        except PoolExhausted:
            # an injected fault can interrupt the claim mid-loop; roll the
            # partial claim back so the failed alloc leaves no leak behind
            for b in popped:
                self.release(b)
            for b in prefix_blocks:
                self.release(b)
            raise
        blocks = prefix_blocks + popped
        self._tables[rid] = blocks
        self._lengths[rid] = int(n_tokens)
        return list(blocks)

    def append_token(self, rid: int) -> int:
        """Reserve room for one more token; grows the table by one block at
        page boundaries. Returns the token's position (the old length).

        The caller must still `ensure_writable(rid, pos)` before physically
        writing: mid-block positions may land in a shared page."""
        pos = self._lengths[rid]
        if pos == len(self._tables[rid]) * self.block_size:
            if not self._free:
                raise PoolExhausted(
                    f"request {rid}: pool exhausted growing past {pos} tokens")
            self._tables[rid].append(self._pop_free())
        self._lengths[rid] = pos + 1
        return pos

    def pop_token(self, rid: int) -> None:
        """Undo the latest `append_token` — a reservation whose decode step
        never ran (the round raised). Only valid immediately after the
        reservation, before any other table mutation for `rid`: the block a
        boundary-crossing append grew is still private, so releasing it
        frees it."""
        n = self._lengths[rid]
        if n <= 0:
            raise ValueError(f"request {rid} has no token to pop")
        self._lengths[rid] = n - 1
        table = self._tables[rid]
        if len(table) > self.blocks_for(n - 1):
            self.release(table.pop())

    def share(self, block: int) -> None:
        """Take an extra reference on an allocated block (prefix cache /
        another table keeping it alive past its owners)."""
        if self._refcounts.get(block, 0) < 1:
            raise ValueError(f"cannot share unallocated block {block}")
        self._refcounts[block] += 1

    def release(self, block: int) -> bool:
        """Drop one reference; returns the block to the pool when the last
        reference falls. True if the block was actually freed."""
        rc = self._refcounts.get(block, 0)
        if rc < 1:
            raise ValueError(f"release of unallocated block {block}")
        if rc == 1:
            del self._refcounts[block]
            self._free.append(block)
            return True
        self._refcounts[block] = rc - 1
        return False

    def ensure_writable(self, rid: int, pos: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write fork: if the page holding position `pos` of `rid`'s
        table is shared (refcount > 1), replace it with a fresh private page.

        Returns ``(src_block, dst_block)`` when a fork happened — the caller
        must copy the page contents src -> dst in the physical pools — else
        None. Raises `PoolExhausted` when a fork is needed but no page is
        free (caller should evict/preempt and retry)."""
        table = self._tables[rid]
        bi = pos // self.block_size
        if bi >= len(table):
            return None  # append_token will grow with a fresh private page
        src = table[bi]
        if self._refcounts[src] == 1:
            return None
        dst = self._pop_free()
        table[bi] = dst
        self.release(src)  # cannot free: refcount was >= 2
        return src, dst

    def free(self, rid: int) -> int:
        """Drop the request's references. Shared pages survive (prefix cache
        or other tables); returns the count actually returned to the pool."""
        blocks = self._tables.pop(rid)
        del self._lengths[rid]
        return sum(1 for b in blocks if self.release(b))

    # ---------------------------------------------------------- invariants

    def check_invariants(self,
                         extra_refs: Optional[Dict[int, int]] = None) -> None:
        """Every usable block is free xor refcounted (owned by one table,
        shared by several, and/or held by the prefix cache); refcounts equal
        table occurrences plus `extra_refs` (e.g. the prefix cache's, via
        `PrefixCache.block_refs()` — omitted means "no external refs").
        Tables are exactly as long as their lengths require, never repeat a
        block, and never contain the garbage page."""
        owner_counts: Dict[int, int] = {}
        for rid, table in self._tables.items():
            n, used = self._lengths[rid], len(table)
            if used != self.blocks_for(n):
                raise AssertionError(
                    f"request {rid}: {used} blocks for {n} tokens")
            if len(set(table)) != len(table):
                raise AssertionError(f"request {rid} lists a block twice")
            for b in table:
                owner_counts[b] = owner_counts.get(b, 0) + 1
        if GARBAGE_BLOCK in owner_counts or GARBAGE_BLOCK in self._refcounts:
            raise AssertionError("the garbage page was allocated")
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate block on the free list")
        refed = set(self._refcounts)
        if free & refed:
            raise AssertionError("a block is both free and refcounted")
        if free | refed != set(range(1, self.num_blocks + 1)):
            raise AssertionError("a block leaked (neither free nor refcounted)")
        for b, rc in self._refcounts.items():
            if rc < 1:
                raise AssertionError(f"block {b} refcounted at {rc}")
        expected = dict(owner_counts)
        for b, n in (extra_refs or {}).items():
            expected[b] = expected.get(b, 0) + n
        if expected != self._refcounts:
            diff = {b: (expected.get(b), self._refcounts.get(b))
                    for b in set(expected) | refed
                    if expected.get(b) != self._refcounts.get(b)}
            raise AssertionError(f"refcount mismatch (expected, actual): {diff}")

"""Paged KV-cache block manager: fixed-size HBM pages, per-request tables.

The serving-side analogue of the paper's far-memory arena: the KV cache is
not a dense ``[batch, max_len]`` allocation but a pool of fixed-size blocks
("pages") in HBM, and each request owns a *block table* — the list of pages
its logical positions map onto. Pages are the coroutine tiles of the paged
decode kernel (`kernels/decode_attention.paged_flash_decode`): the pipeline
fetches them through the table, so physical placement is free and freed
pages are reused immediately (defrag-free by construction — no page ever
needs to move).

This module is pure host-side bookkeeping (no jax): the engine owns the
actual pool arrays and indexes them with the tables produced here.

Layout convention (shared with models.lm / the kernel): block id 0 is a
reserved *garbage* page that is never allocated. Round padding slots point
every table entry at it, so their masked-out scatters/gathers land somewhere
harmless. A pool advertising `num_blocks` usable pages is therefore
physically `num_blocks + 1` blocks (`KVPager.physical_blocks`).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List

import numpy as np

GARBAGE_BLOCK = 0


class PoolExhausted(RuntimeError):
    """No free block available (caller should preempt or wait)."""


class KVPager:
    """Block pool allocator: alloc/append/free with leak-proof accounting."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError(f"need >=1 blocks of >=1 tokens, got "
                             f"{num_blocks}x{block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # block ids 1..num_blocks; 0 is the reserved garbage page
        self._free = deque(range(1, self.num_blocks + 1))
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}

    # ------------------------------------------------------------- queries

    @property
    def physical_blocks(self) -> int:
        """Blocks the engine must allocate (usable pool + garbage page 0)."""
        return self.num_blocks + 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def pool_tokens(self) -> int:
        """Token capacity of the usable pool."""
        return self.num_blocks * self.block_size

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.block_size)

    def can_alloc(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.free_blocks

    def owns(self, rid: int) -> bool:
        return rid in self._tables

    def length(self, rid: int) -> int:
        return self._lengths[rid]

    def block_table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def padded_table(self, rid: int, max_blocks: int) -> np.ndarray:
        """Block table padded with the garbage page to a fixed width."""
        t = self._tables[rid]
        if len(t) > max_blocks:
            raise ValueError(f"request {rid} uses {len(t)} blocks > "
                             f"table width {max_blocks}")
        out = np.full((max_blocks,), GARBAGE_BLOCK, np.int32)
        out[: len(t)] = t
        return out

    # ----------------------------------------------------------- lifecycle

    def alloc(self, rid: int, n_tokens: int) -> List[int]:
        """Claim blocks for `n_tokens` stored tokens (prefill). Returns the
        request's block table; raises `PoolExhausted` leaving state intact."""
        if rid in self._tables:
            raise ValueError(f"request {rid} already has an allocation")
        need = self.blocks_for(n_tokens)
        if need > self.free_blocks:
            raise PoolExhausted(
                f"request {rid}: need {need} blocks, {self.free_blocks} free")
        blocks = [self._free.popleft() for _ in range(need)]
        self._tables[rid] = blocks
        self._lengths[rid] = int(n_tokens)
        return list(blocks)

    def append_token(self, rid: int) -> int:
        """Reserve room for one more token; grows the table by one block at
        page boundaries. Returns the token's position (the old length)."""
        pos = self._lengths[rid]
        if pos == len(self._tables[rid]) * self.block_size:
            if not self._free:
                raise PoolExhausted(
                    f"request {rid}: pool exhausted growing past {pos} tokens")
            self._tables[rid].append(self._free.popleft())
        self._lengths[rid] = pos + 1
        return pos

    def free(self, rid: int) -> int:
        """Release a request's blocks back to the pool. Returns the count."""
        blocks = self._tables.pop(rid)
        del self._lengths[rid]
        self._free.extend(blocks)
        return len(blocks)

    # ---------------------------------------------------------- invariants

    def check_invariants(self) -> None:
        """Every usable block is free xor owned by exactly one request, and
        every table is exactly as long as its length requires."""
        owned: List[int] = []
        for rid, table in self._tables.items():
            n, used = self._lengths[rid], len(table)
            if used != self.blocks_for(n):
                raise AssertionError(
                    f"request {rid}: {used} blocks for {n} tokens")
            owned.extend(table)
        seen = set(owned)
        if len(seen) != len(owned):
            raise AssertionError("a block is owned by two requests")
        if GARBAGE_BLOCK in seen:
            raise AssertionError("the garbage page was allocated")
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate block on the free list")
        if free & seen:
            raise AssertionError("a block is both free and owned")
        if free | seen != set(range(1, self.num_blocks + 1)):
            raise AssertionError("a block leaked (neither free nor owned)")

"""Chunked-prefill executor: prompt KV trickles through the paged pipeline.

The engine's original prefill was monolithic — the whole prompt through the
dense model, then a scatter of its KV into the pages. That stalls every
in-flight decode for the full prompt length and jit-compiles one program per
distinct prompt length. This module replaces it with fixed-size chunks driven
through the *paged* pipeline itself (`models.lm.prefill_chunk_paged`): each
chunk writes its KV rows directly into the request's pages and attends over
everything already resident — including shared prefix pages a cache hit put
in the table, which is what lets a request prefill only its suffix.

Compile discipline: chunk lengths are padded up to powers of two (floored at
one block), so the jit cache holds at most ``log2(max_chunk)`` entries per
table width instead of one per distinct length. `start` / `n_valid` are
traced scalars — moving a chunk along the prompt never recompiles.

Each executed chunk feeds `core.autotune.observe_pipeline` under the
``paged_prefill`` kernel key: wall clock over the page-tiles the chunk's
queries attended, the same latency ledger the decode rounds and the Pallas
pipelines share.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune


def bucket_len(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — the padded chunk length."""
    if n < 1:
        raise ValueError(f"bucket_len needs n >= 1, got {n}")
    return 1 << (max(int(n), int(floor), 1) - 1).bit_length()


class ChunkedPrefiller:
    """Owns the pow2-bucketed jit cache for paged prefill chunk steps."""

    def __init__(self, model, block_size: int):
        self.model = model
        self.block_size = int(block_size)
        self._fns: Dict[Tuple[int, int], Any] = {}  # (padded_len, table_width)
        self._warm: set = set()  # keys whose jit compile was already paid
        self.chunks_run = 0

    def _fn(self, padded: int, table_width: int):
        key = (padded, table_width)
        fn = self._fns.get(key)
        if fn is None:
            model = self.model

            def step(params, k_pools, v_pools, tokens, table, start, n_valid):
                return model.prefill_chunk_paged(
                    params, k_pools, v_pools, table, start,
                    {"tokens": tokens}, n_valid)

            fn = jax.jit(step, donate_argnums=(1, 2))
            self._fns[key] = fn
        return fn

    def run_chunk(self, params, k_pools, v_pools, tokens, table, start: int,
                  n_valid: int):
        """Execute one prefill chunk. `tokens` is the [n_valid] real token
        slice; it is right-padded to its pow2 bucket here. `table` is the
        request's padded block table [table_width]. Returns
        (last_logits [1, V], k_pools, v_pools, wall_s)."""
        if n_valid < 1:
            raise ValueError(f"chunk needs >= 1 tokens, got {n_valid}")
        padded = bucket_len(n_valid, floor=self.block_size)
        buf = np.zeros((1, padded), np.int32)
        buf[0, :n_valid] = np.asarray(tokens, np.int32).reshape(-1)
        table = np.asarray(table, np.int32).reshape(1, -1)
        key = (padded, table.shape[1])
        fn = self._fn(*key)
        warm = key in self._warm
        t0 = time.perf_counter()
        logits, k_pools, v_pools = fn(
            params, k_pools, v_pools, jnp.asarray(buf), jnp.asarray(table),
            jnp.asarray(start, jnp.int32), jnp.asarray(n_valid, jnp.int32))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.chunks_run += 1
        self._warm.add(key)
        # telemetry: wall clock over the page-tiles the chunk attended; the
        # first call per bucket pays jit compile, so only warm calls record
        if warm and autotune.telemetry_enabled():
            tiles = -(-(int(start) + int(n_valid)) // self.block_size)
            autotune.observe_pipeline("paged_prefill", dt, n_tiles=max(tiles, 1))
        return logits, k_pools, v_pools, dt

"""Deterministic fault injection for the paged serving engine.

Disaggregated memory produces exactly the conditions a tidy benchmark never
does — pool exhaustion under bursty admission, reclaim that frees nothing
because every cold page is pinned, latency spikes on the far tier, and
mid-flight step failures. The robustness layer (ISSUE-9, DESIGN.md §2.6)
makes every one of those survivable, and this module makes them
*reproducible*: a seeded `FaultInjector` whose hooks sit behind no-op
singletons in `KVPager`, `ContinuousBatchingScheduler`, and the engine's
round loop, so a chaos run replays the same fault schedule bit-for-bit.

Sites (each hook names one):

  pool_exhausted  - `KVPager._pop_free` raises `PoolExhausted` even though
                    a free block exists (a burst racing us to the pool)
  reclaim_refuse  - the engine's prefix-cache reclaim hook reports 0 pages
                    freed (every cold page pinned elsewhere)
  preempt_refuse  - `_preempt_one` declines to evict a victim (the victim
                    is mid-DMA / unpreemptable), so pressure propagates
  decode          - the jitted decode round raises `InjectedFault`
  prefill         - one prefill chunk raises `InjectedFault`
  latency         - the round loop sleeps a spike before doing work
  kernel_compile  - a kernel-substrate attempt fails like a Mosaic compile
                    error (`core.guard` raises `KernelCompileError`)
  kernel_oom      - a kernel-substrate attempt fails RESOURCE_EXHAUSTED
                    (`KernelResourceError`) — exercises the depth ladder
  kernel_nan      - a successful attempt's output is poisoned non-finite so
                    the always-on scan must catch it (`KernelNumericsError`)

Determinism: every site draws from its **own** `numpy` Generator seeded by
``(seed, site_index)``, so whether one site fires never perturbs another —
the n-th decision at a site depends only on the seed and n. Two runs of the
same workload with the same injector config see the same schedule.

The default `NULL_INJECTOR` is inert: `fire` returns False without drawing,
`check` does nothing, `latency_spike` returns 0.0 — production paths pay
one method call, no branching at the call sites.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Deque, Dict, Optional, Tuple

import numpy as np

__all__ = [
    "DEFAULT_RATES",
    "FaultInjector",
    "InjectedFault",
    "NULL_INJECTOR",
    "SITES",
]

SITES: Tuple[str, ...] = (
    "pool_exhausted",
    "reclaim_refuse",
    "preempt_refuse",
    "decode",
    "prefill",
    "latency",
    # kernel-site streams (ISSUE-10): fired by `core.guard` inside every
    # guarded coro_call (`set_injector`) and by the engine's pre-call
    # `guard.check_injected` hooks. Appended AFTER the seed sites so the
    # (seed, site_index) rng streams of existing sites — and therefore the
    # bit-for-bit replayability of pre-ISSUE-10 chaos schedules — survive.
    "kernel_compile",
    "kernel_oom",
    "kernel_nan",
)

# per-round / per-call firing probabilities of the stock chaos schedule —
# high enough that a 50-round smoke exercises every path, low enough that
# the workload still mostly completes (graceful degradation, not a wall)
DEFAULT_RATES: Dict[str, float] = {
    "pool_exhausted": 0.05,
    "reclaim_refuse": 0.10,
    "preempt_refuse": 0.05,
    "decode": 0.03,
    "prefill": 0.03,
    "latency": 0.05,
    "kernel_compile": 0.03,
    "kernel_oom": 0.02,
    "kernel_nan": 0.02,
}

LOG_CAPACITY = 1024


class InjectedFault(RuntimeError):
    """An exception the injector raised on purpose (site in the message)."""


class FaultInjector:
    """Seeded per-site fault schedule. One instance per engine/chaos run."""

    def __init__(self, seed: int = 0, *,
                 rates: Optional[Dict[str, float]] = None,
                 latency_spike_s: float = 2e-3,
                 max_faults: Optional[int] = None):
        unknown = set(rates or ()) - set(SITES)
        if unknown:
            raise ValueError(f"unknown fault sites {sorted(unknown)}; "
                             f"valid: {SITES}")
        self.seed = int(seed)
        self.rates = dict(DEFAULT_RATES if rates is None else rates)
        self.latency_spike_s = float(latency_spike_s)
        self.max_faults = max_faults
        self.injected = 0
        self.by_site: Dict[str, int] = {}
        self.log: Deque[Tuple[str, Dict[str, Any]]] = deque(maxlen=LOG_CAPACITY)
        self._rngs = {s: np.random.default_rng([self.seed, i])
                      for i, s in enumerate(SITES)}

    @property
    def enabled(self) -> bool:
        return True

    def fire(self, site: str, **ctx) -> bool:
        """One deterministic draw at `site`; True means inject here."""
        rate = self.rates.get(site, 0.0)
        if rate <= 0.0:
            return False
        if self.max_faults is not None and self.injected >= self.max_faults:
            return False
        if self._rngs[site].random() >= rate:
            return False
        self.injected += 1
        self.by_site[site] = self.by_site.get(site, 0) + 1
        self.log.append((site, dict(ctx)))
        return True

    def check(self, site: str, **ctx) -> None:
        """Raise `InjectedFault` when the site fires (step-exception sites)."""
        if self.fire(site, **ctx):
            raise InjectedFault(
                f"injected fault at {site!r} (#{self.injected}, "
                f"seed={self.seed}, ctx={ctx})")

    def latency_spike(self, site: str = "latency") -> float:
        """Seconds to stall when the site fires, else 0.0. The magnitude is
        drawn from the same per-site stream (0.5x..1.5x the nominal)."""
        if not self.fire(site):
            return 0.0
        return self.latency_spike_s * (0.5 + self._rngs[site].random())

    def stats(self) -> Dict[str, Any]:
        return {"seed": self.seed, "injected": self.injected,
                "by_site": dict(self.by_site)}


class _NullInjector:
    """Inert stand-in: the always-on hooks cost one returning method call."""

    seed = None
    injected = 0
    by_site: Dict[str, int] = {}
    log: Tuple = ()
    enabled = False

    def fire(self, site: str, **ctx) -> bool:
        return False

    def check(self, site: str, **ctx) -> None:
        return None

    def latency_spike(self, site: str = "latency") -> float:
        return 0.0

    def stats(self) -> Dict[str, Any]:
        return {"seed": None, "injected": 0, "by_site": {}}


NULL_INJECTOR = _NullInjector()

"""Radix-tree prefix index: shared prompt prefixes map onto shared KV pages.

The serving engine re-fetches (and re-computes) KV for prompt prefixes that
many requests share — system prompts, few-shot headers. This module
deduplicates them at page granularity: a radix tree keyed by full
token-blocks maps a prompt prefix onto the physical pages already holding
its KV. Because KV rows are position-dependent and every shared prefix
starts at position 0, a page can be reused verbatim by any request whose
prompt starts with the same tokens.

Tree shape: one node per cached page; the edge into a node is the exact
`block_size`-token tuple that page stores, so a root-to-node path spells a
block-aligned token prefix. Matching walks full blocks, then takes the
longest common prefix *within* the first diverging block — the partially
matched page is shared too, and the requester copy-on-write forks it
(`KVPager.ensure_writable`) before writing its own suffix rows mid-block.

Lifecycle: `insert` takes one pager reference per cached page (so pages
survive their owning request), `match` only reads, `evict` drops
least-recently-hit leaf pages whose sole remaining reference is the cache —
the engine calls it under pool pressure before resorting to preemption.

Eviction order is kept in a lazy min-heap of ``(last_hit, seq, block)``
entries (seq = node creation order, the tie-break the old full-scan's
strict-< iteration implied): every touch pushes a fresh entry, pops skip
entries whose node was re-touched, evicted, or is currently an interior
node, and candidates that are merely *ineligible right now* (protected, or
still referenced by a live table) are stashed and re-pushed so they stay
candidates. Reclaim is therefore near-linear in pages actually examined
instead of O(nodes x blocks) rescans — it sits on the pool-pressure
critical path (ISSUE-9 satellite). A parent becomes reclaimable the moment
its last child is evicted, at which point it is pushed back into the heap.

A match never covers a whole prompt: at least one token is always left to
prefill so the engine has logits to sample the first output token from.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.serve.kv_pager import KVPager


def _lcp(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


@dataclasses.dataclass
class PrefixMatch:
    """A prefix-cache lookup result: `blocks` shared pages covering the
    first `n_tokens` prompt tokens (the last page possibly only partially —
    ``n_tokens % block_size`` rows valid)."""

    blocks: List[int]
    n_tokens: int

    @property
    def hit(self) -> bool:
        return self.n_tokens > 0


MISS = PrefixMatch([], 0)


class _Node:
    __slots__ = ("tokens", "block", "parent", "children", "last_hit", "seq")

    def __init__(self, tokens: Tuple[int, ...], block: int,
                 parent: Optional["_Node"], seq: int):
        self.tokens = tokens
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_hit = 0
        self.seq = seq  # creation order: the LRU heap's tie-break


class PrefixCache:
    """Radix index over the block pool; holds one pager ref per cached page."""

    def __init__(self, pager: KVPager):
        self.pager = pager
        self.block_size = pager.block_size
        self._children: Dict[Tuple[int, ...], _Node] = {}  # root level
        self._by_block: Dict[int, _Node] = {}
        self._heap: List[Tuple[int, int, int]] = []  # (last_hit, seq, block)
        self._seq = 0
        self._clock = 0
        self.lookups = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._by_block)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _touch(self, node: _Node, now: int) -> None:
        """Stamp a hit and push the node's fresh heap entry (lazy: the
        previous entries go stale and are skipped at pop time)."""
        node.last_hit = now
        heapq.heappush(self._heap, (now, node.seq, node.block))
        if len(self._heap) > max(64, 8 * len(self._by_block)):
            # long-lived processes: compact the stale backlog in one pass
            self._heap = [(n.last_hit, n.seq, n.block)
                          for n in self._by_block.values()]
            heapq.heapify(self._heap)

    # -------------------------------------------------------------- match

    def match(self, tokens: Sequence[int]) -> PrefixMatch:
        """Longest cached prefix of `tokens`, capped at ``len(tokens) - 1``
        so the requester always prefills (and gets logits for) at least one
        token. Takes no references — `KVPager.alloc(prefix_blocks=...)`
        does, immediately after, under the same engine step."""
        self.lookups += 1
        toks = [int(t) for t in tokens]
        blk = self.block_size
        now = self._tick()
        blocks: List[int] = []
        covered = 0
        children = self._children
        while True:
            key = tuple(toks[covered:covered + blk])
            node = children.get(key) if len(key) == blk else None
            if node is not None:  # whole block matches: descend
                self._touch(node, now)
                blocks.append(node.block)
                covered += blk
                children = node.children
                continue
            # divergence: share the child page with the longest common
            # prefix inside this block (COW-forked by the requester before
            # it writes its own rows there)
            rest = toks[covered:]
            best, best_n = None, 0
            for child in children.values():
                n = _lcp(child.tokens, rest)
                if n > best_n:
                    best, best_n = child, n
            if best is not None:
                self._touch(best, now)
                blocks.append(best.block)
                covered += best_n
            break
        if covered >= len(toks):
            covered = len(toks) - 1
        while blocks and covered <= (len(blocks) - 1) * blk:
            blocks.pop()  # capping dropped the tail page entirely
        if covered <= 0:
            return MISS
        return PrefixMatch(blocks, covered)

    # ------------------------------------------------------------- insert

    def insert(self, tokens: Sequence[int], table_blocks: Sequence[int]) -> int:
        """Register the *full* blocks of `tokens` (a prompt prefix whose KV
        is final in `table_blocks`, the owning request's table). Pages new
        to the tree gain a cache reference; paths already present are kept
        (the request's duplicate page stays private). Returns pages added."""
        blk = self.block_size
        n_full = len(tokens) // blk
        toks = [int(t) for t in tokens]
        children = self._children
        parent: Optional[_Node] = None
        now = self._tick()
        added = 0
        for i in range(n_full):
            key = tuple(toks[i * blk:(i + 1) * blk])
            node = children.get(key)
            if node is None:
                block = int(table_blocks[i])
                if block in self._by_block:
                    break  # page already backs another path; stop extending
                node = _Node(key, block, parent, self._seq)
                self._seq += 1
                children[key] = node
                self._by_block[block] = node
                self.pager.share(block)
                added += 1
            self._touch(node, now)
            parent = node
            children = node.children
        return added

    # ------------------------------------------------------------- evict

    def evict(self, n_blocks: int,
              protect: FrozenSet[int] = frozenset()) -> List[int]:
        """Free up to `n_blocks` pages: least-recently-hit leaves whose only
        remaining reference is the cache itself (never pages still in a
        live table, never `protect`). Evicting a leaf may expose its parent
        as the next candidate. Returns the freed page ids.

        Heap-driven (see module docstring): pops the LRU candidate instead
        of rescanning every node per freed block; ineligible-for-now
        entries are stashed and re-pushed on exit."""
        evicted: List[int] = []
        stash: List[Tuple[int, int, int]] = []
        heap = self._heap
        while heap and len(evicted) < n_blocks:
            entry = heapq.heappop(heap)
            t, seq, block = entry
            node = self._by_block.get(block)
            if node is None or node.seq != seq or node.last_hit != t:
                continue  # stale: evicted, block reused, or re-touched
            if node.children:
                continue  # interior; re-pushed when its last child goes
            if block in protect or self.pager.refcount(block) != 1:
                stash.append(entry)  # ineligible now, still a candidate
                continue
            siblings = node.parent.children if node.parent else self._children
            del siblings[node.tokens]
            del self._by_block[block]
            self.pager.release(block)
            evicted.append(block)
            self.evictions += 1
            parent = node.parent
            if parent is not None and not parent.children:
                heapq.heappush(heap, (parent.last_hit, parent.seq,
                                      parent.block))
        for entry in stash:
            heapq.heappush(heap, entry)
        return evicted

    # -------------------------------------------------------------- misc

    def block_refs(self) -> Dict[int, int]:
        """Per-page cache references, for `KVPager.check_invariants`."""
        return {b: 1 for b in self._by_block}

    def stats(self) -> Dict[str, int]:
        return {"cached_blocks": len(self._by_block),
                "lookups": self.lookups,
                "evictions": self.evictions}

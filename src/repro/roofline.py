"""Roofline accounting: analytic model FLOPs, HLO parsing, machine terms.

The hardware model is the active `core.machine` profile (one `MachineModel`
definition shared with the depth solver — default TPU v5e-class: peak bf16
compute 197 TFLOP/s | HBM 819 GB/s | ICI ~50 GB/s per link; dial with
``REPRO_MACHINE``). The legacy names `PEAK_FLOPS`/`HBM_BW`/`ICI_BW` resolve
to the active profile via module `__getattr__`.

The three terms, per (arch x shape x mesh), all **per chip** (the compiled
SPMD module is the per-device program, so cost_analysis is per-device):

  compute    = HLO_FLOPs / peak_flops
  memory     = HLO_bytes / hbm_bw
  collective = collective_bytes / ici_bw
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSuite, cache_seq_len, token_split
from repro.core.machine import MachineModel, get_machine

# ------------------------------------------------------------ HLO parsing

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in optimized HLO text."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # paired with the -start we already counted
        op = m.group(1)
        operand_region = line[m.end():]
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(operand_region))
        if total == 0:
            # fall back to the output shape (left of '=')
            lhs = line[: m.start()]
            total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
        out[op] += total
    return dict(out)


# ------------------------------------------------------- HBM traffic model

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[a-z]\d*[a-z]*\d*\[[\d,]*\])")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_NO_TRAFFIC = {"parameter", "constant", "bitcast", "tuple", "get-tuple-element",
               "convert", "copy", "after-all", "partition-id", "iota"}


def hbm_bytes(hlo_text: str) -> float:
    """TPU-oriented HBM-traffic estimate from optimized HLO: for every
    top-level (entry) op, count output bytes + operand bytes, skipping ops
    the TPU performs for free or that the CPU backend inserts artificially
    (`convert` — the CPU emulates bf16 dots via f32 upcasts; DESIGN.md §3.2).
    Fusions count only their boundary tensors, matching real HBM traffic.
    """
    sizes: dict = {}
    total = 0.0
    in_entry = False
    # pass 1: sizes of every instruction (any computation)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shp = _SHAPE_RE.search(m.group(2))
            if shp:
                sizes[m.group(1)] = _shape_bytes(shp.group(1), shp.group(2))
    # pass 2: entry-computation traffic
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
            continue
        if in_entry and line.startswith("}"):
            in_entry = False
            continue
        if not in_entry:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = line.split("=", 1)[1].strip()
        opcode_m = re.match(r"(?:\(?[a-z]\d*[a-z]*\d*\[[\d,]*\]\)?(?:\{[\d,]*\})?\s+)?([\w\-]+)\(", rhs)
        opcode = opcode_m.group(1) if opcode_m else ""
        if opcode in _NO_TRAFFIC:
            continue
        out_b = sizes.get(m.group(1), 0)
        paren = rhs[rhs.find("(") + 1: rhs.find(")")] if "(" in rhs else ""
        operands = _OPND_RE.findall(paren)
        name = m.group(1)
        if opcode == "fusion" and len(operands) == 1 and (
            "convert" in name or name.startswith(("wrapped_slice", "slice_bitcast"))
        ):
            # CPU-backend artifacts: bf16<->f32 upcast wrappers (TPU-native
            # dtype) and leading-dim parameter slices (views on TPU)
            continue
        total += out_b + sum(sizes.get(name_, 0) for name_ in operands)
    return total


# --------------------------------------------------------- analytic FLOPs


def _flops_params(cfg: ArchConfig) -> int:
    """Matmul-active parameters (embedding lookup excluded, unembed included)."""
    n = cfg.n_active_params()
    if not cfg.tie_embeddings:
        n -= cfg.vocab * cfg.d_model  # lookup table does no matmul flops
    return n


def model_flops(cfg: ArchConfig, shape: ShapeSuite, kind: str) -> float:
    """Analytic 'useful' FLOPs per step, whole job (divide by chips for/chip).

    train: 6*N*D + attention (causal 12*B*S^2*H*hd per... see DESIGN);
    MoE uses N_active. Attention/SSM mixer terms included since they dominate
    the 32k/500k shapes.
    """
    b = shape.global_batch
    front, text = token_split(cfg, shape.seq_len)
    hd = cfg.resolved_head_dim
    h = cfg.n_heads
    L = cfg.n_layers

    if kind == "train":
        tokens = b * (text + front)
        mult = 6.0
    elif kind == "prefill":
        tokens = b * (text + front)
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = b
        mult = 2.0

    flops = mult * _flops_params(cfg) * tokens

    # mixer terms
    if kind in ("train", "prefill"):
        s = text + (front if not cfg.enc_dec else 0)
        eff = min(cfg.sliding_window, s) if cfg.sliding_window else s
        if cfg.has_attention:
            # fwd = 4*B*S*eff*H*hd (qk+pv), /2 causal; train multiplies by 3
            a = 2.0 * b * s * eff * h * hd * L
            if cfg.enc_dec:
                a = 2.0 * b * front * front * h * hd * cfg.n_enc_layers \
                    + 2.0 * b * text * text * h * hd * L \
                    + 4.0 * b * text * front * h * hd * L  # cross (not causal)
            flops += a * (3.0 if kind == "train" else 1.0)
        if cfg.ssm or cfg.hybrid:
            q = cfg.ssm_chunk
            n = cfg.ssm_state
            nh, p = cfg.ssm_heads, cfg.ssm_head_dim
            ssd = 2.0 * b * s * (q * n + q * nh * p + 2.0 * n * nh * p) * L
            flops += ssd * (3.0 if kind == "train" else 1.0)
    else:
        if cfg.has_attention:
            s_kv = cache_seq_len(cfg, shape)
            flops += 4.0 * b * s_kv * h * hd * L
            if cfg.enc_dec:
                flops += 4.0 * b * shape.seq_len * h * hd * L  # cross over enc
        if cfg.ssm or cfg.hybrid:
            flops += 4.0 * b * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * L

    return flops


# ---------------------------------------------------------------- terms


def terms(per_chip_flops: float, per_chip_bytes: float,
          coll_bytes: Dict[str, int],
          *, machine: Optional[MachineModel] = None) -> Dict[str, float]:
    """Roofline terms under `machine` (default: the active profile — the
    SAME model `core.schedule.solve_depth` hides latency against)."""
    m = machine or get_machine()
    total_coll = float(sum(coll_bytes.values()))
    return {
        "compute_s": per_chip_flops / m.peak_flops,
        "memory_s": per_chip_bytes / m.hbm_bw,
        "collective_s": total_coll / m.ici_bw if m.ici_bw else 0.0,
    }


def dominant(t: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])


def __getattr__(name: str):
    # PEAK_FLOPS / HBM_BW / ICI_BW forward to the active machine profile —
    # the single definition is core.machine (ISSUE-6 acceptance criterion).
    if name in ("PEAK_FLOPS", "HBM_BW", "ICI_BW"):
        from repro.core import machine as _machine

        return getattr(_machine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

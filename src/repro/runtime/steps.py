"""Step builders: train / prefill / decode with full sharding annotations.

These are the functions the launcher jits and the dry-run lowers — one per
shape-suite kind. Gradient accumulation (microbatching) runs as a lax.scan so
each microbatch's gradient reduce-scatter can overlap the next microbatch's
backward under XLA's latency-hiding scheduler.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSuite, batch_specs, decode_batch_specs
from repro.models import params as pm
from repro.models.registry import Model
from repro.optim import AdamWConfig, apply_updates, init_state
from repro.sharding import ShardingCtx


# ----------------------------------------------------------------- sharding


def batch_shardings(ctx: ShardingCtx, specs: Dict[str, jax.ShapeDtypeStruct]):
    if ctx.mesh is None:
        return None
    out = {}
    for k, v in specs.items():
        axes = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(ctx.mesh, ctx.spec(axes, v.shape))
    return out


def state_shardings(model: Model):
    ps = model.param_shardings()
    if ps is None:
        return None
    rep = NamedSharding(model.ctx.mesh, P())
    return {"step": rep, "params": ps, "mu": ps, "nu": ps}


def abstract_state(model: Model):
    p = model.abstract_params()
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "params": p,
        "mu": p,
        "nu": p,
    }


# -------------------------------------------------------------------- train


def make_train_step(model: Model, opt: AdamWConfig, *, accum: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(state, batch):
        if accum > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(state["params"], mb)
                gacc = jax.tree.map(jnp.add, gacc, g)
                return (gacc, lacc + loss), None

            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(state["params"], batch)
        new_state, opt_metrics = apply_updates(state, grads, opt)
        metrics = {"loss": loss, **opt_metrics}
        return new_state, metrics

    return train_step


def jit_train_step(model: Model, opt: AdamWConfig, *, accum: int = 1, donate: bool = True):
    fn = make_train_step(model, opt, accum=accum)
    ctx = model.ctx
    if ctx.mesh is None:
        return jax.jit(fn, donate_argnums=(0,) if donate else ())
    ss = state_shardings(model)
    bs = None  # propagate from input constraint
    return jax.jit(
        fn,
        in_shardings=(ss, bs),
        out_shardings=(ss, None),
        donate_argnums=(0,) if donate else (),
    )


# -------------------------------------------------------------------- serve


def make_prefill_step(model: Model, *, pad_to: Optional[int] = None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, pad_to=pad_to)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)
    return decode_step


def jit_decode_step(model: Model, shape: ShapeSuite):
    fn = make_decode_step(model)
    ctx = model.ctx
    if ctx.mesh is None:
        return jax.jit(fn, donate_argnums=(1,))
    ps = model.param_shardings()
    cs = model.cache_shardings(shape)
    return jax.jit(
        fn,
        in_shardings=(ps, cs, None),
        out_shardings=(None, cs),
        donate_argnums=(1,),
    )

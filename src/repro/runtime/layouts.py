"""Named sharding-layout presets (§Perf findings as first-class configs).

  training  - DP/FSDP + TP: parameters and optimizer state storage-sharded
              over the data axis (ZeRO) on top of tensor parallelism.
              Right for train steps: the per-layer weight all-gather
              amortizes over thousands of tokens per step.
  serving   - pure TP residency: no data-axis storage sharding. Decode
              touches every weight once per token, so FSDP re-gathers are
              pure overhead — §Perf measured 30x (dense 104B) and 110x (MoE)
              cross-chip traffic reductions from this preset, plus bf16
              weight residency.
"""
from __future__ import annotations

from typing import Dict, Optional

TRAINING: Optional[Dict] = None  # the DEFAULT_RULES in repro.sharding

SERVING: Dict = {
    "embed": None,   # no FSDP storage sharding
    "fsdp": None,
}


def rules_for(layout: str):
    if layout in ("training", "default"):
        return TRAINING
    if layout == "serving":
        return dict(SERVING)
    raise ValueError(f"unknown layout {layout!r} (training|serving)")


def serving_config_overrides() -> Dict:
    """ArchConfig overrides that pair with the serving layout."""
    return {"param_dtype": "bfloat16", "cache_update": "row"}

"""Training loop: steps + checkpoints + straggler monitor + exact resume."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, MarkovTask
from repro.models.registry import Model
from repro.optim import AdamWConfig, init_state
from repro.optim.compression import ef_compress, init_error_state
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.runtime.steps import make_train_step, state_shardings


@dataclasses.dataclass
class TrainReport:
    steps: int
    losses: Dict[int, float]
    resumed_from: Optional[int]
    straggler_steps: int
    wall_s: float


def train(model: Model, *, steps: int, data_cfg: DataConfig,
          opt: Optional[AdamWConfig] = None, accum: int = 1,
          compress_grads: bool = False, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, log_every: int = 10, seed: int = 0,
          fail_at_step: Optional[int] = None) -> TrainReport:
    """Run `steps` optimizer steps; resumes exactly from `ckpt_dir` if present.

    `fail_at_step` injects a crash (fault-tolerance tests / demos).
    """
    t_start = time.time()
    opt = opt or AdamWConfig(total_steps=steps)
    task = MarkovTask(data_cfg)

    if compress_grads:
        # compress gradients with error feedback before the update
        def step_fn(state, batch):
            err = state.pop("grad_error")

            def loss_fn(params, b):
                loss, m = model.loss(params, b)
                return loss, m

            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch)
            grads, err = ef_compress(grads, err)
            from repro.optim import apply_updates
            new_state, om = apply_updates(state, grads, opt)
            new_state["grad_error"] = err
            return new_state, {"loss": loss, **om}
    else:
        step_fn = make_train_step(model, opt, accum=accum)

    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    # ---- init or resume
    resumed_from = None
    params = model.init(jax.random.PRNGKey(seed))
    state = init_state(params)
    if compress_grads:
        state["grad_error"] = init_error_state(params)
    if ckpt_dir is not None and latest_step(ckpt_dir) is not None:
        resumed_from = latest_step(ckpt_dir)
        state = restore(ckpt_dir, state)
        state = jax.tree.map(jnp.asarray, state)

    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir is not None else None
    monitor = StragglerMonitor()
    losses: Dict[int, float] = {}

    start = int(state["step"])
    for step in range(start, steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in task.batch_for_step(step).items()}
        with monitor.timed():
            state, metrics = jit_step(state, batch)
        if step % log_every == 0 or step == steps - 1:
            losses[step] = float(metrics["loss"])
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            ckpt.save_async(state, step + 1)
    if ckpt is not None:
        ckpt.save_async(state, steps)
        ckpt.wait()
    return TrainReport(steps=steps, losses=losses, resumed_from=resumed_from,
                       straggler_steps=len(monitor.flagged),
                       wall_s=time.time() - t_start)

"""Fault tolerance: restart supervision and straggler detection.

Scale-out posture (DESIGN.md §3.1): at 1000+ nodes the unit of recovery is
the *job step*, not the process — the data pipeline is a pure function of the
step counter and checkpoints are atomic, so any failure maps to "restore the
last checkpoint, continue".

  * run_with_restarts  — supervisor: retries the step loop after transient
    failures, restoring state via the caller's restore_fn. Kernel-substrate
    failures (`core.guard.SubstrateError`, DESIGN.md §2.7) are retriable by
    construction — they subclass RuntimeError — and their kernel context
    (kernel / machine / depth) is recorded in `RestartReport.failures` so a
    post-mortem can tell a dying node from a bad kernel config. Note the
    supervisor is the *outer* ring: inside a step, `guarded_call` already
    walked its depth ladder and twin fallback; a SubstrateError reaching
    here means strict mode or a family with no degradation path.
  * StragglerMonitor   — per-step latency tracker flagging outliers
    (> threshold x running median); the launcher logs and, in a real
    deployment, triggers hot-spare swap / re-shard for persistent offenders.

(The seed-era `elastic_mesh_shape` helper is gone: elastic restore is
template-based in `checkpointing.checkpoint.restore`, and nothing else
consumed the mesh math.)
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Callable, Deque, List, Tuple

from repro.core.guard import SubstrateError


@dataclasses.dataclass
class RestartReport:
    restarts: int
    failures: List[str]
    completed: bool


def _describe_failure(e: BaseException) -> str:
    """One log line per failure; SubstrateError carries kernel context."""
    if isinstance(e, SubstrateError):
        ctx = f"kernel={e.kernel} machine={e.machine}"
        if e.depth is not None:
            ctx += f" depth={e.depth}"
        return f"{type(e).__name__}[{ctx}]: {e}"
    return f"{type(e).__name__}: {e}"


def run_with_restarts(step_loop: Callable[[], None], *,
                      restore_fn: Callable[[], None],
                      max_restarts: int = 3,
                      retriable=(RuntimeError, OSError)) -> RestartReport:
    """Supervise `step_loop`; on retriable failure, restore and re-enter."""
    failures: List[str] = []
    for attempt in range(max_restarts + 1):
        try:
            step_loop()
            return RestartReport(attempt, failures, True)
        except retriable as e:  # noqa: PERF203
            failures.append(_describe_failure(e))
            if attempt == max_restarts:
                break
            restore_fn()
    return RestartReport(max_restarts, failures, False)


class StragglerMonitor:
    """Flags steps (or, fed per-host timings, hosts) slower than
    `threshold` x running median — the paper's variable-latency concern at
    cluster scale; the mitigation hook is pluggable."""

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.samples: Deque[float] = collections.deque(maxlen=window)
        self.flagged: List[Tuple[int, float]] = []
        self._step = 0

    def record(self, duration_s: float) -> bool:
        """Returns True if this sample is a straggler."""
        self._step += 1
        is_straggler = False
        if len(self.samples) >= max(self.window // 4, 4):
            med = statistics.median(self.samples)
            if duration_s > self.threshold * med:
                self.flagged.append((self._step, duration_s))
                is_straggler = True
        self.samples.append(duration_s)
        return is_straggler

    def timed(self):
        return _Timer(self)


class _Timer:
    def __init__(self, mon: StragglerMonitor):
        self.mon = mon

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.straggler = self.mon.record(time.perf_counter() - self.t0)
        return False

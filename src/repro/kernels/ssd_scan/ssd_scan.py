"""Chunked SSD (Mamba-2) scan kernel with streaming state.

Grid = (batch, chunks): each step processes one sequence chunk; the
recurrent state [H,P,N] lives in VMEM scratch across chunk steps (the
paper's "sequential" variable class — core/context.py) and resets at each
new batch element. Chunk inputs (x, dt, B, C) stream HBM->VMEM through
Pallas's BlockSpec pipeline, which is the compiler-generated form of the
same decoupled issue/wait mechanism the manual kernels spell out (the block
for step i+1 is being DMA'd while step i computes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_s, *,
                chunk: int, nh: int, p: int, n: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        h_s[...] = jnp.zeros_like(h_s)

    x = x_ref[0].astype(jnp.float32)      # [chunk, nh, p]
    dt = dt_ref[0].astype(jnp.float32)    # [chunk, nh]
    B = b_ref[0].astype(jnp.float32)      # [chunk, n]
    C = c_ref[0].astype(jnp.float32)      # [chunk, n]
    A = a_ref[...].astype(jnp.float32)    # [nh]

    dA = dt * A                            # [chunk, nh] (<=0)
    cs = jnp.cumsum(dA, axis=0)
    total = cs[-1]                         # [nh]
    dtx = x * dt[..., None]                # [chunk, nh, p]
    scores = C @ B.T                       # [chunk, chunk]
    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    ys = []
    h_next = []
    for h in range(nh):
        seg = cs[:, None, h] - cs[None, :, h]
        L = jnp.exp(seg) * causal
        y_intra = (scores * L) @ dtx[:, h]
        h_prev = h_s[h]                                    # [p, n]
        y_inter = jnp.exp(cs[:, h])[:, None] * (C @ h_prev.T)
        ys.append(y_intra + y_inter)
        decay_to_end = jnp.exp(total[h] - cs[:, h])
        s_chunk = (B * decay_to_end[:, None]).T @ dtx[:, h]  # [n, p]
        h_next.append(h_prev * jnp.exp(total[h]) + s_chunk.T)

    y_ref[...] = jnp.stack(ys, axis=1).astype(y_ref.dtype)[None]
    for h in range(nh):
        h_s[h] = h_next[h]

    @pl.when(ci == n_chunks - 1)
    def _():
        hout_ref[...] = h_s[...].astype(hout_ref.dtype)[None]


def ssd_scan(x, dt, A, B, C, *, chunk: int = 64, interpret: bool = True):
    """Batched SSD. x:[b,s,nh,p] dt:[b,s,nh] A:[nh] B,C:[b,s,n].

    Returns (y [b,s,nh,p], h_final [b,nh,p,n]).
    """
    bsz, s, nh, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    n_chunks = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nh=nh, p=p, n=n,
                               n_chunks=n_chunks)
    out = pl.pallas_call(
        kernel,
        grid=(bsz, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, nh, p), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, chunk, nh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((nh,), lambda b, i: (0,)),
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, nh, p), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, nh, p, n), lambda b, i: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, nh, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, nh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nh, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return out[0], out[1]

"""Chunked SSD (Mamba-2) scan kernel declared as a `CoroSpec`.

Grid = (batch,): each grid step scans one sequence. Chunk inputs (x, dt, B,
C) are four `LoadStream`s — each chunk's four DMAs form one aset group on a
slot semaphore and `depth` chunks are in flight while earlier chunks
compute. The recurrent state [H,P,N] is declared as a *sequential* context
var (order-dependent update — core/context.py classifies it one-copy,
depth-independent) and the builder derives its scratch; it resets at each
batch element in the prologue. ``depth=None`` solves the depth from the
spec's chunk profile via core.autotune.

Note the intra-chunk math is order-free; only the [H,P,N] state carries the
sequential dependence, so deep pipelining of chunk *loads* is safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import context as ctx_mod
from repro.core.coro import CoroSpec, LoadStream, coro_call


def ssd_spec(chunk: int, nh: int, p: int, n: int, dtype,
             *, seq_len: int | None = None) -> CoroSpec:
    """Chunk tile: x/dt/B/C stream per slot; the recurrent state is
    sequential (one copy) and the per-batch y/h-out blocks are residents."""
    itemsize = jnp.dtype(dtype).itemsize

    def chunk_src(ref_name):
        def src(ctx, t):
            ref = getattr(ctx, ref_name)
            return ref.at[ctx.pids[0], pl.ds(t * chunk, chunk)]
        return src

    return CoroSpec(
        name="ssd_scan",
        loads=(
            LoadStream("x", (chunk, nh, p), dtype, src=chunk_src("x_hbm")),
            LoadStream("dt", (chunk, nh), dtype, src=chunk_src("dt_hbm")),
            LoadStream("bmat", (chunk, n), dtype, src=chunk_src("b_hbm")),
            LoadStream("cmat", (chunk, n), dtype, src=chunk_src("c_hbm")),
        ),
        vars=(
            # recurrent state: order-dependent update -> SEQUENTIAL, one copy
            ctx_mod.var("h", (nh, p, n), jnp.float32,
                        carries_dependence=True),
            # per-batch residents: h-out f32 block + y output block
            ctx_mod.VarSpec("h_out_block", nbytes=4 * nh * p * n,
                            hint=ctx_mod.VarClass.SHARED),
            ctx_mod.VarSpec("y_block",
                            nbytes=(seq_len or chunk) * nh * p * itemsize,
                            hint=ctx_mod.VarClass.SHARED),
        ),
        flops_per_tile=float(2 * chunk * chunk * (n + nh * p)),
    )


def ssd_scan(x, dt, A, B, C, *, chunk: int = 64, depth: int | None = None,
             interpret: bool = True):
    """Batched SSD. x:[b,s,nh,p] dt:[b,s,nh] A:[nh] B,C:[b,s,n].

    Returns (y [b,s,nh,p], h_final [b,nh,p,n]).
    """
    bsz, s, nh, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    n_chunks = s // chunk
    spec = ssd_spec(chunk, nh, p, n, x.dtype, seq_len=s)

    def prologue(ctx):
        ctx.h[...] = jnp.zeros_like(ctx.h)  # fresh state per batch element
        A_f = ctx.a[...].astype(jnp.float32)          # [nh]
        causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
        return (A_f, causal)

    def body(ctx, tile, slot, carry):
        A_f, causal = carry
        xc = ctx.x[slot].astype(jnp.float32)     # [chunk, nh, p]
        dtc = ctx.dt[slot].astype(jnp.float32)   # [chunk, nh]
        Bc = ctx.bmat[slot].astype(jnp.float32)  # [chunk, n]
        Cc = ctx.cmat[slot].astype(jnp.float32)  # [chunk, n]

        dA = dtc * A_f                          # [chunk, nh] (<=0)
        cs = jnp.cumsum(dA, axis=0)
        total = cs[-1]                          # [nh]
        dtx = xc * dtc[..., None]               # [chunk, nh, p]
        scores = Cc @ Bc.T                      # [chunk, chunk]

        ys = []
        h_next = []
        for hh in range(nh):
            seg = cs[:, None, hh] - cs[None, :, hh]
            L = jnp.exp(seg) * causal
            y_intra = (scores * L) @ dtx[:, hh]
            h_prev = ctx.h[hh]                                   # [p, n]
            y_inter = jnp.exp(cs[:, hh])[:, None] * (Cc @ h_prev.T)
            ys.append(y_intra + y_inter)
            decay_to_end = jnp.exp(total[hh] - cs[:, hh])
            s_chunk = (Bc * decay_to_end[:, None]).T @ dtx[:, hh]  # [n, p]
            h_next.append(h_prev * jnp.exp(total[hh]) + s_chunk.T)

        ctx.y[0, pl.ds(tile * chunk, chunk)] = jnp.stack(
            ys, axis=1).astype(ctx.y.dtype)
        for hh in range(nh):
            ctx.h[hh] = h_next[hh]
        return carry

    def epilogue(ctx, carry):
        ctx.h_out[...] = ctx.h[...].astype(ctx.h_out.dtype)[None]

    out = coro_call(
        spec, x, dt, A, B, C,
        n_tiles=n_chunks, depth=depth, body=body,
        prologue=prologue, epilogue=epilogue,
        arg_names=("x_hbm", "dt_hbm", "a", "b_hbm", "c_hbm", "y", "h_out"),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),            # x
            pl.BlockSpec(memory_space=pl.ANY),            # dt
            pl.BlockSpec((nh,), lambda b: (0,)),          # A (small, resident)
            pl.BlockSpec(memory_space=pl.ANY),            # B
            pl.BlockSpec(memory_space=pl.ANY),            # C
        ],
        out_specs=[
            pl.BlockSpec((1, s, nh, p), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, nh, p, n), lambda b: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, nh, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, nh, p, n), jnp.float32),
        ],
        interpret=interpret,
    )
    return out[0], out[1]

"""Chunked SSD (Mamba-2) scan kernel with streaming state.

Grid = (batch,): each grid step scans one sequence; the recurrent state
[H,P,N] lives in VMEM scratch across chunks (the paper's "sequential"
variable class — core/context.py, one copy regardless of depth) and resets
at each batch element. Chunk inputs (x, dt, B, C) stream HBM->VMEM through
`core.coro.coro_loop` in fori mode: each chunk's four DMAs form one aset
group on a slot semaphore and `depth` chunks are in flight while earlier
chunks compute — the same decoupled issue/wait substrate as the manual
gather kernels, replacing the compiler-chosen BlockSpec double-buffering
(``depth=None`` solves the depth from the chunk profile via core.autotune).

Note the intra-chunk math is order-free; only the [H,P,N] state carries the
sequential dependence, so deep pipelining of chunk *loads* is safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import autotune
from repro.core.coro import coro_loop, wait_block


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                x_slots, dt_slots, b_slots, c_slots, sems, h_s, *,
                depth: int, chunk: int, nh: int, p: int, n: int,
                n_chunks: int):
    b_i = pl.program_id(0)

    def issue(tile, slot):
        start = tile * chunk
        for ref, buf in ((x_ref, x_slots), (dt_ref, dt_slots),
                         (b_ref, b_slots), (c_ref, c_slots)):
            pltpu.make_async_copy(ref.at[b_i, pl.ds(start, chunk)],
                                  buf.at[slot], sems.at[slot]).start()

    def wait(tile, slot):
        for buf in (x_slots, dt_slots, b_slots, c_slots):
            wait_block(buf.at[slot], sems.at[slot])

    h_s[...] = jnp.zeros_like(h_s)  # fresh state per batch element
    A = a_ref[...].astype(jnp.float32)         # [nh]
    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def consume(tile, slot, carry):
        x = x_slots[slot].astype(jnp.float32)    # [chunk, nh, p]
        dt = dt_slots[slot].astype(jnp.float32)  # [chunk, nh]
        B = b_slots[slot].astype(jnp.float32)    # [chunk, n]
        C = c_slots[slot].astype(jnp.float32)    # [chunk, n]

        dA = dt * A                             # [chunk, nh] (<=0)
        cs = jnp.cumsum(dA, axis=0)
        total = cs[-1]                          # [nh]
        dtx = x * dt[..., None]                 # [chunk, nh, p]
        scores = C @ B.T                        # [chunk, chunk]

        ys = []
        h_next = []
        for h in range(nh):
            seg = cs[:, None, h] - cs[None, :, h]
            L = jnp.exp(seg) * causal
            y_intra = (scores * L) @ dtx[:, h]
            h_prev = h_s[h]                                    # [p, n]
            y_inter = jnp.exp(cs[:, h])[:, None] * (C @ h_prev.T)
            ys.append(y_intra + y_inter)
            decay_to_end = jnp.exp(total[h] - cs[:, h])
            s_chunk = (B * decay_to_end[:, None]).T @ dtx[:, h]  # [n, p]
            h_next.append(h_prev * jnp.exp(total[h]) + s_chunk.T)

        y_ref[0, pl.ds(tile * chunk, chunk)] = jnp.stack(
            ys, axis=1).astype(y_ref.dtype)
        for h in range(nh):
            h_s[h] = h_next[h]
        return carry

    coro_loop(n_chunks, depth, issue, consume, wait)

    hout_ref[...] = h_s[...].astype(hout_ref.dtype)[None]


def ssd_scan(x, dt, A, B, C, *, chunk: int = 64, depth: int | None = None,
             interpret: bool = True):
    """Batched SSD. x:[b,s,nh,p] dt:[b,s,nh] A:[nh] B,C:[b,s,n].

    Returns (y [b,s,nh,p], h_final [b,nh,p,n]).
    """
    bsz, s, nh, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    n_chunks = s // chunk
    if depth is None:
        depth = autotune.choose_depth(
            autotune.profile_ssd(chunk, nh, p, n, x.dtype.itemsize,
                                 seq_len=s),
            kernel="ssd_scan")
    depth = min(depth, n_chunks)

    kernel = functools.partial(_ssd_kernel, depth=depth, chunk=chunk, nh=nh,
                               p=p, n=n, n_chunks=n_chunks)
    out = pl.pallas_call(
        kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),            # x
            pl.BlockSpec(memory_space=pl.ANY),            # dt
            pl.BlockSpec((nh,), lambda b: (0,)),          # A (small, resident)
            pl.BlockSpec(memory_space=pl.ANY),            # B
            pl.BlockSpec(memory_space=pl.ANY),            # C
        ],
        out_specs=[
            pl.BlockSpec((1, s, nh, p), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, nh, p, n), lambda b: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, nh, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, nh, p, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((depth, chunk, nh, p), x.dtype),
            pltpu.VMEM((depth, chunk, nh), dt.dtype),
            pltpu.VMEM((depth, chunk, n), B.dtype),
            pltpu.VMEM((depth, chunk, n), C.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.VMEM((nh, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B, C)
    return out[0], out[1]

"""Public SSD-scan op.

``depth=None`` solves the number of in-flight chunk loads from the
declared `CoroSpec` (`ssd_scan.ssd_spec`) via core.autotune — the
sequential recurrent state is one copy regardless of depth, so it caps
the budget once, not per slot.
"""
from __future__ import annotations

from repro.core.machine import default_interpret
from repro.kernels.ssd_scan.ssd_scan import ssd_scan


def ssd(x, dt, A, B, C, *, chunk: int = 64, depth: int | None = None,
        interpret: bool | None = None):
    """Batched SSD. x:[b,s,nh,p] dt:[b,s,nh] A:[nh] B,C:[b,s,n]."""
    interpret = default_interpret() if interpret is None else interpret
    return ssd_scan(x, dt, A, B, C, chunk=chunk, depth=depth,
                    interpret=interpret)


# -------- fallback twin (core.guard degradation path, ISSUE-10) --------
from repro.kernels import register_twin  # noqa: E402


def _ssd_twin(spec, x, dt, A, B, C):
    # same chunking as the kernel (spec.loads[0] is the x chunk stream), so
    # the parity sentinel compares like-for-like chunked math
    import jax.numpy as jnp

    from repro.models.ssm import ssd_chunked
    chunk = spec.loads[0].tile[0]
    y, h_final = ssd_chunked(x, dt, A, B, C, chunk)
    return [y.astype(x.dtype), h_final.astype(jnp.float32)]


register_twin("ssd_scan", _ssd_twin)

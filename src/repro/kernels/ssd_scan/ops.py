"""Public SSD-scan op.

``depth=None`` solves the number of in-flight chunk loads from the
declared `CoroSpec` (`ssd_scan.ssd_spec`) via core.autotune — the
sequential recurrent state is one copy regardless of depth, so it caps
the budget once, not per slot.
"""
from __future__ import annotations

from repro.core.machine import default_interpret
from repro.kernels.ssd_scan.ssd_scan import ssd_scan


def ssd(x, dt, A, B, C, *, chunk: int = 64, depth: int | None = None,
        interpret: bool | None = None):
    """Batched SSD. x:[b,s,nh,p] dt:[b,s,nh] A:[nh] B,C:[b,s,n]."""
    interpret = default_interpret() if interpret is None else interpret
    return ssd_scan(x, dt, A, B, C, chunk=chunk, depth=depth,
                    interpret=interpret)

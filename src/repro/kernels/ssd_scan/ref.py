"""Oracle for the SSD-scan kernel: the sequential recurrence (models.ssm)."""
from repro.models.ssm import ssd_sequential, ssd_chunked  # noqa: F401

ssd_ref = ssd_sequential

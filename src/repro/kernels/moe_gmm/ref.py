"""Oracle for the grouped-matmul (expert FFN) kernel."""
import jax.numpy as jnp


def gmm_ref(tokens, weights):
    """tokens: [E, C, dm]; weights: [E, dm, f] -> [E, C, f]."""
    return jnp.einsum("ecd,edf->ecf", tokens, weights)

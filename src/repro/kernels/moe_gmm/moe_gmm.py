"""Grouped matmul for MoE experts with streamed weight tiles.

In expert-parallel MoE the *weights* are the far-memory objects: each local
expert's [dm, f] matrix is streamed HBM->VMEM tile-by-tile while the MXU
consumes the previous tile — the coroutine pipeline with weight tiles as the
in-flight context (CoroAMU's HJ build side). BlockSpec tiling supplies the
double-buffered schedule; block shapes keep MXU dims at 128-multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(t_ref, w_ref, o_ref):
    # t: [1, C, dm], w: [1, dm, ft] -> o: [1, C, ft]
    o_ref[...] = jnp.einsum(
        "cd,df->cf", t_ref[0], w_ref[0],
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)[None]


def gmm(tokens, weights, *, f_tile: int = 128, interpret: bool = True):
    """tokens: [E, C, dm]; weights: [E, dm, f] -> [E, C, f]."""
    e, c, dm = tokens.shape
    f = weights.shape[-1]
    assert f % f_tile == 0
    grid = (e, f // f_tile)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dm), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, dm, f_tile), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, c, f_tile), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), tokens.dtype),
        interpret=interpret,
    )(tokens, weights)

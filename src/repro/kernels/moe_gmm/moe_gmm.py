"""Grouped matmul for MoE experts with streamed weight tiles.

In expert-parallel MoE the *weights* are the far-memory objects: each local
expert's [dm, f] matrix is streamed HBM->VMEM tile-by-tile while the MXU
consumes the previous tile — the coroutine pipeline with weight tiles as the
in-flight context (CoroAMU's HJ build side). Each tile is a strided DMA
window [dm, f_tile] of the expert's weight matrix (no host-side relayout:
the weights stream from their native [E, dm, f] layout); the pipeline is
`core.coro.coro_loop` in fori mode with `depth` weight tiles in flight
(``depth=None`` solves it from the tile profile via core.autotune),
replacing the fixed double-buffering BlockSpec supplied before.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import autotune
from repro.core.coro import coro_loop, wait_block


def _gmm_kernel(t_ref, w_ref, o_ref, slots, sems, *, depth: int,
                f_tile: int, n_tiles: int):
    e_i = pl.program_id(0)

    def issue(tile, slot):
        pltpu.make_async_copy(
            w_ref.at[e_i, :, pl.ds(tile * f_tile, f_tile)],
            slots.at[slot], sems.at[slot]).start()

    def wait(tile, slot):
        wait_block(slots.at[slot], sems.at[slot])

    tokens = t_ref[0]  # [c, dm]

    def consume(tile, slot, carry):
        o_ref[0, :, pl.ds(tile * f_tile, f_tile)] = jnp.einsum(
            "cd,df->cf", tokens, slots[slot],
            preferred_element_type=jnp.float32,
        ).astype(o_ref.dtype)
        return carry

    coro_loop(n_tiles, depth, issue, consume, wait)


def gmm(tokens, weights, *, f_tile: int = 128, depth: int | None = None,
        interpret: bool = True):
    """tokens: [E, C, dm]; weights: [E, dm, f] -> [E, C, f]."""
    e, c, dm = tokens.shape
    f = weights.shape[-1]
    assert f % f_tile == 0
    n_tiles = f // f_tile
    if depth is None:
        depth = autotune.choose_depth(
            autotune.profile_gmm(c, dm, f_tile, weights.dtype.itemsize,
                                 f_total=f),
            kernel="moe_gmm")
    depth = min(depth, n_tiles)

    kernel = functools.partial(_gmm_kernel, depth=depth, f_tile=f_tile,
                               n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, c, dm), lambda i: (i, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, c, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), tokens.dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, dm, f_tile), weights.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        interpret=interpret,
    )(tokens, weights)

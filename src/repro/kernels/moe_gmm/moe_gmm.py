"""Grouped matmul for MoE experts: streamed weight tiles as a `CoroSpec`.

In expert-parallel MoE the *weights* are the far-memory objects: each local
expert's [dm, f] matrix is streamed HBM->VMEM tile-by-tile while the MXU
consumes the previous tile — the coroutine pipeline with weight tiles as the
in-flight context (CoroAMU's HJ build side). Each tile is a strided DMA
window [dm, f_tile] of the expert's weight matrix (no host-side relayout:
the weights stream from their native [E, dm, f] layout). The declaration is
one `LoadStream` plus accounting vars for the depth-independent residents
(the token block and the expert's full output block, both hint-SHARED); the
pipeline is `core.coro.coro_call` in fori mode with `depth` weight tiles in
flight (``depth=None`` solves it from the spec's profile via core.autotune).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import context as ctx_mod
from repro.core.coro import CoroSpec, LoadStream, coro_call


def gmm_spec(c: int, dm: int, f_tile: int, dtype,
             *, f_total: int | None = None) -> CoroSpec:
    """Streamed expert-weight tile; the token block AND the expert's full
    [c, f] output block are depth-independent VMEM residents."""
    itemsize = jnp.dtype(dtype).itemsize
    return CoroSpec(
        name="moe_gmm",
        loads=(LoadStream(
            "w", (dm, f_tile), dtype,
            src=lambda ctx, t: ctx.w_hbm.at[ctx.pids[0], :,
                                            pl.ds(t * f_tile, f_tile)],
        ),),
        vars=(
            # operand/output blocks resident across the whole expert:
            # accounting-only (materialized by the BlockSpecs, not scratch)
            ctx_mod.VarSpec("tokens", nbytes=c * dm * itemsize,
                            read_only=True),
            ctx_mod.VarSpec("y_block", nbytes=c * (f_total or f_tile) * itemsize,
                            hint=ctx_mod.VarClass.SHARED),
        ),
        flops_per_tile=float(2 * c * dm * f_tile),
    )


def gmm(tokens, weights, *, f_tile: int = 128, depth: int | None = None,
        interpret: bool = True):
    """tokens: [E, C, dm]; weights: [E, dm, f] -> [E, C, f]."""
    e, c, dm = tokens.shape
    f = weights.shape[-1]
    assert f % f_tile == 0
    n_tiles = f // f_tile
    spec = gmm_spec(c, dm, f_tile, weights.dtype, f_total=f)

    def prologue(ctx):
        return ctx.t[0]  # [c, dm] token block for this expert

    def body(ctx, t, slot, carry):
        ctx.o[0, :, pl.ds(t * f_tile, f_tile)] = jnp.einsum(
            "cd,df->cf", carry, ctx.w[slot],
            preferred_element_type=jnp.float32,
        ).astype(ctx.o.dtype)
        return carry

    return coro_call(
        spec, tokens, weights,
        n_tiles=n_tiles, depth=depth, body=body, prologue=prologue,
        arg_names=("t", "w_hbm", "o"),
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, c, dm), lambda i: (i, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, c, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), tokens.dtype),
        interpret=interpret,
    )

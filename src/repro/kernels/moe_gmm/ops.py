"""Public grouped-matmul op.

``depth=None`` solves the number of in-flight weight tiles from the
declared `CoroSpec` (`moe_gmm.gmm_spec`) via core.autotune, with the VMEM
cap taken from the classified context bytes.
"""
from __future__ import annotations

from repro.core.machine import default_interpret
from repro.kernels.moe_gmm.moe_gmm import gmm


def moe_gmm(tokens, weights, *, f_tile: int = 128, depth: int | None = None,
            interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return gmm(tokens, weights, f_tile=f_tile, depth=depth, interpret=interpret)


# -------- fallback twin (core.guard degradation path, ISSUE-10) --------
from repro.kernels import register_twin  # noqa: E402


def _gmm_twin(spec, tokens, weights):
    from repro.kernels.moe_gmm.ref import gmm_ref
    return gmm_ref(tokens, weights).astype(tokens.dtype)


register_twin("moe_gmm", _gmm_twin)

"""Public grouped-matmul op.

``depth=None`` solves the number of in-flight weight tiles from the
declared `CoroSpec` (`moe_gmm.gmm_spec`) via core.autotune, with the VMEM
cap taken from the classified context bytes.
"""
from __future__ import annotations

from repro.core.machine import default_interpret
from repro.kernels.moe_gmm.moe_gmm import gmm


def moe_gmm(tokens, weights, *, f_tile: int = 128, depth: int | None = None,
            interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return gmm(tokens, weights, f_tile=f_tile, depth=depth, interpret=interpret)

"""Public grouped-matmul op."""
from __future__ import annotations

import jax

from repro.kernels.moe_gmm.moe_gmm import gmm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def moe_gmm(tokens, weights, *, f_tile: int = 128, interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return gmm(tokens, weights, f_tile=f_tile, interpret=interpret)

"""Public scatter-add op: dedup (await/asignal analogue) + pipelined RMW.

The RMW store pipeline itself (drain-before-reuse + epilogue drain) is the
substrate's shared `StoreStream` path — declared in
`coro_scatter_add.scatter_add_spec`, implemented once in `core.coro`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.descriptors import dedup_rmw
from repro.core.machine import default_interpret
from repro.kernels.coro_scatter_add.coro_scatter_add import scatter_add_unique


def coro_scatter_add(table, idx, updates, *, depth: int | None = None,
                     rows_per_tile: int = 8, interpret: bool | None = None):
    """table[idx[i]] += updates[i] with duplicates combined up front.

    The dedup is the compile-time replacement for the paper's await/asignal
    coroutine locks (DESIGN.md §2.1): after it, no two in-flight slots can
    target the same row, so the RMW pipeline is race-free by construction.
    `idx` is host data (plan-time pass).
    """
    interpret = default_interpret() if interpret is None else interpret
    uniq, summed = dedup_rmw(np.asarray(idx), np.asarray(updates))
    n = uniq.shape[0]
    pad = (-n) % rows_per_tile
    if pad:
        # pad with distinct out-of-range-free rows: reuse row 0..pad-1 of the
        # table with zero updates is unsafe (duplicates) — instead pad with
        # rows beyond the used set via a zero-update self-write on unique
        # sentinel rows taken from the deduped complement. Simplest safe pad:
        # replicate the LAST unique row with zero update is still a duplicate
        # in-flight hazard only if it lands in a different tile; keep it in
        # the same tile by padding with ascending unused ids when possible.
        all_ids = np.arange(table.shape[0])
        unused = np.setdiff1d(all_ids, uniq)[:pad]
        if unused.shape[0] < pad:
            raise ValueError("cannot pad: every row is a scatter target")
        uniq = np.concatenate([uniq, unused.astype(uniq.dtype)])
        summed = np.concatenate(
            [summed, np.zeros((pad,) + summed.shape[1:], summed.dtype)]
        )
    return scatter_add_unique(
        table, jnp.asarray(uniq, jnp.int32), jnp.asarray(summed),
        depth=depth, rows_per_tile=rows_per_tile, interpret=interpret,
    )


# -------- fallback twin (core.guard degradation path, ISSUE-10) --------
from repro.kernels import register_twin  # noqa: E402


def _scatter_add_twin(spec, idx, table, updates):
    from repro.kernels.coro_scatter_add.ref import scatter_add_ref
    return scatter_add_ref(table, idx, updates)


register_twin("scatter_add", _scatter_add_twin)

"""Coroutine scatter-add: pipelined read-modify-write with decoupled DMA.

GUPS's update side (and embedding-gradient / histogram scatter). Each tile:
  aload rows -> wait -> add updates -> astore rows -> (slot reused later)

The warmup/rotation schedule is `core.coro.coro_loop` in grid mode; the
RMW-specific store pipeline lives in the consume callback (drain the slot's
previous store, compute, start the new store) plus an epilogue drain after
the rotation retires.

Hazards:
  * duplicate rows across in-flight tiles would race; the paper serializes
    with await/asignal locks — our compile-time analogue is the sort+dedup
    transform in ops.py (each row is written exactly once; see
    core.descriptors.dedup_rmw).
  * slot reuse: a slot's next load may overwrite data still being stored.
    in_slots/out_slots are separate, and the store semaphore is awaited
    before the slot's output buffer is rewritten.

The table is updated in place via input_output_aliasing (the SPM region the
paper manages in L2 is the VMEM slot set here; HBM is the far memory).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import autotune
from repro.core.coro import coro_loop, issue_rows, wait_rows


def _scatter_add_kernel(idx_ref, table_ref, upd_ref, out_ref, in_slots,
                        out_slots, load_sems, store_sems, *, depth: int,
                        rows_per_tile: int, n_tiles: int):
    i = pl.program_id(0)

    def rows_of(tile):
        return [idx_ref[tile * rows_per_tile + j] for j in range(rows_per_tile)]

    def issue_load(tile, slot):
        issue_rows(out_ref, rows_of(tile), in_slots.at[slot], load_sems.at[slot])

    def start_store(tile, slot):
        for j, r in enumerate(rows_of(tile)):
            pltpu.make_async_copy(
                out_slots.at[slot, pl.ds(j, 1)],
                out_ref.at[pl.ds(r, 1)],
                store_sems.at[slot],
            ).start()

    def wait_store(slot):
        for j in range(rows_per_tile):
            pltpu.make_async_copy(
                out_slots.at[slot, pl.ds(j, 1)],
                out_slots.at[slot, pl.ds(j, 1)],
                store_sems.at[slot],
            ).wait()

    def wait_load(tile, slot):
        wait_rows(in_slots.at[slot], load_sems.at[slot], rows_per_tile)

    def consume(tile, slot, carry):
        # drain the slot's previous store before rewriting its output buffer
        @pl.when(tile >= depth)
        def _():
            wait_store(slot)

        out_slots[slot] = in_slots[slot] + upd_ref[...]
        start_store(tile, slot)
        return carry

    coro_loop(n_tiles, depth, issue_load, consume, wait_load, grid_step=i)

    # final drain: every slot has exactly one outstanding store at the end
    # (earlier ones were drained before their buffer was rewritten)
    @pl.when(i == n_tiles - 1)
    def _():
        for s in range(min(depth, n_tiles)):
            wait_store(s)


def scatter_add_unique(table, idx, updates, *, depth: int | None = None,
                       rows_per_tile: int = 8, interpret: bool = True):
    """In-place pipelined RMW. `idx` must be duplicate-free (see ops.py)."""
    n = idx.shape[0]
    assert n % rows_per_tile == 0
    n_tiles = n // rows_per_tile
    d = table.shape[1]
    if depth is None:
        depth = autotune.choose_depth(
            autotune.profile_scatter_add(rows_per_tile, d, table.dtype.itemsize),
            kernel="scatter_add")
    depth = min(depth, n_tiles)

    kernel = functools.partial(
        _scatter_add_kernel, depth=depth, rows_per_tile=rows_per_tile,
        n_tiles=n_tiles,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # table (aliased to out)
            pl.BlockSpec((rows_per_tile, d), lambda i, idx_ref: (i, 0)),  # updates
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((depth, rows_per_tile, d), table.dtype),
            pltpu.VMEM((depth, rows_per_tile, d), table.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={1: 0},  # table (operand 1 incl. prefetch) -> out
        interpret=interpret,
    )(idx, table, updates)

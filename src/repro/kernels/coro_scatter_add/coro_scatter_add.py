"""Coroutine scatter-add: pipelined read-modify-write as a `CoroSpec`.

GUPS's update side (and embedding-gradient / histogram scatter). Each tile:
  aload rows -> wait -> add updates -> astore rows -> (slot reused later)

The kernel is a declaration: one `LoadStream` reading the target rows, one
`StoreStream` writing them back, and a one-line body. All RMW plumbing —
drain-the-slot's-previous-store before the body rewrites it, start the new
write-back after, epilogue drain once the rotation retires — is the
substrate's shared `StoreStream` path (`core.coro.coro_pipeline`), the same
code stream_copy rides.

Hazards:
  * duplicate rows across in-flight tiles would race; the paper serializes
    with await/asignal locks — our compile-time analogue is the sort+dedup
    transform in ops.py (each row is written exactly once; see
    core.descriptors.dedup_rmw).
  * slot reuse: a slot's next load may overwrite data still being stored.
    Load and store streams get separate slot buffers, and the store
    semaphore is drained before the slot's output buffer is rewritten.

The table is updated in place via input_output_aliasing (the SPM region the
paper manages in L2 is the VMEM slot set here; HBM is the far memory).
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl

from repro.core.coro import CoroSpec, LoadStream, StoreStream, coro_call


def scatter_add_spec(rows_per_tile: int, d: int, dtype) -> CoroSpec:
    """RMW tile: rows are loaded AND stored (2x traffic, 2x slot VMEM)."""
    def row_slices(ctx, t):
        return [ctx.out.at[pl.ds(ctx.idx[t * rows_per_tile + j], 1)]
                for j in range(rows_per_tile)]

    return CoroSpec(
        name="scatter_add",
        loads=(LoadStream("cur", (rows_per_tile, d), dtype,
                          src=row_slices, group=rows_per_tile),),
        stores=(StoreStream("acc", (rows_per_tile, d), dtype,
                            dst=row_slices, group=rows_per_tile),),
        flops_per_tile=float(2 * rows_per_tile * d),
    )


def scatter_add_unique(table, idx, updates, *, depth: int | None = None,
                       rows_per_tile: int = 8, interpret: bool = True):
    """In-place pipelined RMW. `idx` must be duplicate-free (see ops.py)."""
    n = idx.shape[0]
    assert n % rows_per_tile == 0
    n_tiles = n // rows_per_tile
    d = table.shape[1]
    spec = scatter_add_spec(rows_per_tile, d, table.dtype)

    def body(ctx, t, slot, carry):
        ctx.acc[slot] = ctx.cur[slot] + ctx.upd[...]
        return carry

    return coro_call(
        spec, idx, table, updates,
        n_tiles=n_tiles, depth=depth, body=body,
        arg_names=("idx", "table", "upd", "out"),
        grid=(n_tiles,), drive_axis=0,
        num_scalar_prefetch=1,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # table (aliased to out)
            pl.BlockSpec((rows_per_tile, d), lambda i, idx_ref: (i, 0)),  # updates
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={1: 0},  # table (operand 1 incl. prefetch) -> out
        interpret=interpret,
    )

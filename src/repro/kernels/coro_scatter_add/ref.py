"""Oracle for the coroutine scatter-add (GUPS update / histogram / MoE combine)."""
from __future__ import annotations

import jax.numpy as jnp


def scatter_add_ref(table, idx, updates):
    """table[idx[i]] += updates[i] (duplicates accumulate)."""
    return table.at[idx].add(updates)

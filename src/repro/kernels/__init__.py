"""Coroutine kernel families — and their jnp-twin fallback registry.

Every family ships a pure-jnp oracle (`ref.py`); ISSUE-10's guarded
substrate (`core.guard`) uses those oracles as *fallback twins*: when a
kernel exhausts its depth-backoff ladder (or its circuit breaker is open,
or the parity sentinel catches a divergence) the registered twin computes
the answer instead, so a `coro_call` never surfaces an unhandled
`SubstrateError` on a family with a twin.

Each family's `ops.py` registers its adapters at import time via
`register_twin(spec_name, fn)`; an adapter has the signature
``fn(spec, *operands) -> out`` where `operands` are exactly the positional
operands the family passed to `coro_call` and `out` matches the pallas
output structure. Resolution is lazy: `fallback_twin` imports the six
`ops` modules on first use, so importing `repro.kernels` stays free of
jax-tracing side effects and the core -> kernels import edge only exists
at fallback time (no cycle with `core.coro`).
"""
from __future__ import annotations

import importlib
from typing import Any, Callable, Dict, List, Optional

__all__ = ["fallback_twin", "register_twin", "registered_twins"]

_FAMILIES = (
    "coro_gather",
    "coro_scatter_add",
    "decode_attention",
    "moe_gmm",
    "ssd_scan",
    "stream_copy",
)

_TWINS: Dict[str, Callable[..., Any]] = {}
_loaded = False


def register_twin(name: str, fn: Callable[..., Any]) -> None:
    """Register `fn(spec, *operands)` as the fallback twin for the
    `CoroSpec` named `name` (called by each family's ops.py on import)."""
    _TWINS[name] = fn


def _ensure_registered() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for family in _FAMILIES:
        importlib.import_module(f"repro.kernels.{family}.ops")


def fallback_twin(name: str) -> Optional[Callable[..., Any]]:
    """The registered twin for spec `name`, or None (no degradation path)."""
    _ensure_registered()
    return _TWINS.get(name)


def registered_twins() -> List[str]:
    _ensure_registered()
    return sorted(_TWINS)

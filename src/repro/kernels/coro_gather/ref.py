"""Pure-jnp oracle for the coroutine gather kernels."""
from __future__ import annotations

import jax.numpy as jnp


def gather_ref(table, idx):
    """out[i] = table[idx[i]] — the GUPS / hash-probe / embedding pattern."""
    return jnp.take(table, idx, axis=0)


def gather_scale_ref(table, idx, scale=1.0):
    return jnp.take(table, idx, axis=0) * scale

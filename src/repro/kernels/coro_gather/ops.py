"""Public ops for the coroutine gather: padding, coalescing, auto-depth.

``depth=None`` on either entry point solves the pipeline depth from the
declared `CoroSpec`'s tile profile via core.autotune (VMEM cap from the
classified context bytes; adaptive once transfer samples are recorded —
see autotune.record_transfer). The coalesced path threads the same
auto-depth into both sub-pipelines, so span DMAs and single-row aset
groups share one declarative substrate codepath (`core.coro.coro_call`).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.descriptors import GatherPlan, plan_gather
from repro.core.machine import default_interpret
from repro.kernels.coro_gather.coro_gather import row_gather, span_gather


def coro_gather(table, idx, *, depth: int | None = None, rows_per_tile: int = 8,
                interpret: bool | None = None):
    """Pipelined gather; pads the index stream to a tile multiple."""
    interpret = default_interpret() if interpret is None else interpret
    n = idx.shape[0]
    pad = (-n) % rows_per_tile
    idx_p = jnp.pad(idx, (0, pad)) if pad else idx
    out = row_gather(table, idx_p.astype(jnp.int32), depth=depth,
                     rows_per_tile=rows_per_tile, interpret=interpret)
    return out[:n]


def coalesced_gather(table, idx: np.ndarray, *, span: int = 8,
                     depth: int | None = None, interpret: bool | None = None):
    """Coalesced gather (paper §III-C): span DMAs + single-row leftovers.

    `idx` is host data (the plan is a compile-time pass, like the paper's
    greedy basic-block scheduling). Returns (out, plan) so callers can report
    the coalescing ratio. Both sub-pipelines ride `coro_call`; each solves
    its own depth when `depth` is None (span tiles and row tiles have
    different specs).
    """
    interpret = default_interpret() if interpret is None else interpret
    plan = plan_gather(np.asarray(idx), span=span)
    d = table.shape[1]
    parts = []
    if plan.n_spans:
        parts.append(span_gather(table, jnp.asarray(plan.span_starts),
                                 span=span, depth=depth, interpret=interpret))
    if plan.n_singles:
        parts.append(coro_gather(table, jnp.asarray(plan.singles),
                                 rows_per_tile=min(8, max(plan.n_singles, 1)),
                                 depth=depth, interpret=interpret))
    if not parts:
        return jnp.zeros((0, d), table.dtype), plan
    flat = jnp.concatenate(parts, axis=0)
    return flat[jnp.asarray(plan.order)], plan


# -------- fallback twins (core.guard degradation path, ISSUE-10) --------
from repro.kernels import register_twin  # noqa: E402


def _row_gather_twin(spec, idx, table):
    from repro.kernels.coro_gather.ref import gather_ref
    return gather_ref(table, idx)


def _span_gather_twin(spec, starts, table):
    # spec.loads[0] is the span stream: tile = (span, d)
    span = spec.loads[0].tile[0]
    rows = (starts[:, None] + jnp.arange(span, dtype=starts.dtype)).reshape(-1)
    return jnp.take(table, rows, axis=0)


register_twin("row_gather", _row_gather_twin)
register_twin("span_gather", _span_gather_twin)

"""Coroutine gather kernel: random-row gather with decoupled DMA pipeline.

The paper's flagship pattern (GUPS read side, hash-join probe, embedding
lookup). Each grid step consumes one tile of `rows_per_tile` gathered rows;
`depth` tiles are in flight at once, each tile's rows being an `aset` group
of row-DMAs bound to one slot semaphore. Both variants drive
`core.coro.coro_loop` in grid mode — the warmup/rotation schedule lives in
the substrate, only the issue/wait/consume callbacks differ:

  * row gather  — one DMA per requested row (uncoalesced aset group).
  * span gather — one DMA per `span` contiguous rows (the coarse-grained
    request of §III-C; fed by core.descriptors.plan_gather).

With ``depth=None`` the entry points solve the depth from the tile's
profile via core.autotune (latency-aware, VMEM-capped).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import autotune
from repro.core.coro import coro_loop, issue_rows, wait_block, wait_rows


def _row_gather_kernel(idx_ref, table_ref, out_ref, slots, sems, *,
                       depth: int, rows_per_tile: int, n_tiles: int):
    def issue(tile, slot):
        rows = [idx_ref[tile * rows_per_tile + j] for j in range(rows_per_tile)]
        issue_rows(table_ref, rows, slots.at[slot], sems.at[slot])

    def wait(tile, slot):
        wait_rows(slots.at[slot], sems.at[slot], rows_per_tile)

    def consume(tile, slot, carry):
        out_ref[...] = slots[slot]
        return carry

    coro_loop(n_tiles, depth, issue, consume, wait, grid_step=pl.program_id(0))


def row_gather(table, idx, *, depth: int | None = None, rows_per_tile: int = 8,
               interpret: bool = True):
    """out[i] = table[idx[i]]. idx length must divide into rows_per_tile."""
    n = idx.shape[0]
    assert n % rows_per_tile == 0, (n, rows_per_tile)
    n_tiles = n // rows_per_tile
    d = table.shape[1]
    if depth is None:
        depth = autotune.choose_depth(
            autotune.profile_row_gather(rows_per_tile, d, table.dtype.itemsize),
            kernel="row_gather")
    depth = min(depth, n_tiles)

    kernel = functools.partial(
        _row_gather_kernel, depth=depth, rows_per_tile=rows_per_tile,
        n_tiles=n_tiles,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((rows_per_tile, d), lambda i, idx_ref: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((depth, rows_per_tile, d), table.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(idx, table)


def _span_gather_kernel(starts_ref, table_ref, out_ref, slots, sems, *,
                        depth: int, span: int, n_tiles: int):
    def issue(tile, slot):
        pltpu.make_async_copy(
            table_ref.at[pl.ds(starts_ref[tile], span)],
            slots.at[slot],
            sems.at[slot],
        ).start()

    def wait(tile, slot):
        wait_block(slots.at[slot], sems.at[slot])

    def consume(tile, slot, carry):
        out_ref[...] = slots[slot]
        return carry

    coro_loop(n_tiles, depth, issue, consume, wait, grid_step=pl.program_id(0))


def span_gather(table, starts, *, span: int = 8, depth: int | None = None,
                interpret: bool = True):
    """out[i*span:(i+1)*span] = table[starts[i]:starts[i]+span]."""
    n_tiles = starts.shape[0]
    d = table.shape[1]
    if depth is None:
        depth = autotune.choose_depth(
            autotune.profile_span_gather(span, d, table.dtype.itemsize),
            kernel="span_gather")
    depth = min(depth, max(n_tiles, 1))
    if n_tiles == 0:
        return jnp.zeros((0, d), table.dtype)

    kernel = functools.partial(
        _span_gather_kernel, depth=depth, span=span, n_tiles=n_tiles,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((span, d), lambda i, starts_ref: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((depth, span, d), table.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles * span, d), table.dtype),
        interpret=interpret,
    )(starts, table)

"""Coroutine gather kernel: random-row gather declared as a `CoroSpec`.

The paper's flagship pattern (GUPS read side, hash-join probe, embedding
lookup). Each grid step consumes one tile of gathered rows; `depth` tiles
are in flight at once. Both variants are pure declarations — one
`LoadStream` plus a two-line body — and ride `core.coro.coro_call` in grid
mode, which derives the slot scratch, DMA semaphores, and the
warmup/rotation schedule from the spec:

  * row gather  — one DMA per requested row (an aset group of
    `rows_per_tile` copies bound to one slot semaphore).
  * span gather — one DMA per `span` contiguous rows (the coarse-grained
    request of §III-C; fed by core.descriptors.plan_gather).

With ``depth=None`` the entry points solve the depth from the spec's tile
profile via core.autotune (latency-aware, VMEM cap from the classified
context bytes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coro import CoroSpec, LoadStream, coro_call


def row_gather_spec(rows_per_tile: int, d: int, dtype) -> CoroSpec:
    """One tile = `rows_per_tile` single-row DMAs (an aset group)."""
    return CoroSpec(
        name="row_gather",
        loads=(LoadStream(
            "rows", (rows_per_tile, d), dtype,
            src=lambda ctx, t: [
                ctx.table.at[pl.ds(ctx.idx[t * rows_per_tile + j], 1)]
                for j in range(rows_per_tile)
            ],
            group=rows_per_tile,
        ),),
        flops_per_tile=float(rows_per_tile * d),
    )


def span_gather_spec(span: int, d: int, dtype) -> CoroSpec:
    """One tile = one coarse-grained span DMA (paper §III-C case 1)."""
    return CoroSpec(
        name="span_gather",
        loads=(LoadStream(
            "span", (span, d), dtype,
            src=lambda ctx, t: ctx.table.at[pl.ds(ctx.starts[t], span)],
        ),),
        flops_per_tile=float(span * d),
    )


def row_gather(table, idx, *, depth: int | None = None, rows_per_tile: int = 8,
               interpret: bool = True):
    """out[i] = table[idx[i]]. idx length must divide into rows_per_tile."""
    n = idx.shape[0]
    assert n % rows_per_tile == 0, (n, rows_per_tile)
    n_tiles = n // rows_per_tile
    d = table.shape[1]
    spec = row_gather_spec(rows_per_tile, d, table.dtype)

    def body(ctx, t, slot, carry):
        ctx.out[...] = ctx.rows[slot]
        return carry

    return coro_call(
        spec, idx, table,
        n_tiles=n_tiles, depth=depth, body=body,
        arg_names=("idx", "table", "out"),
        grid=(n_tiles,), drive_axis=0,
        num_scalar_prefetch=1,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((rows_per_tile, d), lambda i, idx_ref: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )


def span_gather(table, starts, *, span: int = 8, depth: int | None = None,
                interpret: bool = True):
    """out[i*span:(i+1)*span] = table[starts[i]:starts[i]+span]."""
    n_tiles = starts.shape[0]
    d = table.shape[1]
    if n_tiles == 0:
        return jnp.zeros((0, d), table.dtype)
    spec = span_gather_spec(span, d, table.dtype)

    def body(ctx, t, slot, carry):
        ctx.out[...] = ctx.span[slot]
        return carry

    return coro_call(
        spec, starts, table,
        n_tiles=n_tiles, depth=depth, body=body,
        arg_names=("starts", "table", "out"),
        grid=(n_tiles,), drive_axis=0,
        num_scalar_prefetch=1,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((span, d), lambda i, starts_ref: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * span, d), table.dtype),
        interpret=interpret,
    )

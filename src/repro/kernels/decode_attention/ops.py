"""Public flash-decode op with latency-aware depth selection."""
from __future__ import annotations

import jax

from repro.core.schedule import TileProfile, solve_depth
from repro.kernels.decode_attention.decode_attention import flash_decode


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, pos, *, blk: int = 128,
                     depth: int | None = None, interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    if depth is None:
        _, h, d = q.shape
        kh = k_cache.shape[2]
        tile_bytes = 2 * blk * kh * d * k_cache.dtype.itemsize
        flops = 4.0 * blk * h * d  # qk + pv per block
        depth = min(solve_depth(TileProfile(tile_bytes=tile_bytes,
                                            flops_per_tile=flops)), 8)
    return flash_decode(q, k_cache, v_cache, pos, blk=blk, depth=depth,
                        interpret=interpret)

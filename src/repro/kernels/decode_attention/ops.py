"""Public flash-decode op with latency-aware depth selection.

``depth=None`` solves the pipeline depth from the KV-block `CoroSpec`
(`decode_attention.decode_spec`) via core.autotune — the VMEM cap comes
from the classified context bytes (shared online-softmax accumulators
don't multiply by depth), adaptive once transfer samples are recorded.
"""
from __future__ import annotations

from repro.core.machine import default_interpret
from repro.kernels.decode_attention.decode_attention import (
    flash_decode,
    paged_flash_decode,
)


def decode_attention(q, k_cache, v_cache, pos, *, blk: int = 128,
                     depth: int | None = None, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return flash_decode(q, k_cache, v_cache, pos, blk=blk, depth=depth,
                        interpret=interpret)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           depth: int | None = None,
                           interpret: bool | None = None):
    """Ragged-batch decode over a paged KV block pool (see
    `decode_attention.paged_flash_decode`). ``depth=None`` solves the
    pipeline depth from the page-tile `CoroSpec` via core.autotune."""
    interpret = default_interpret() if interpret is None else interpret
    return paged_flash_decode(q, k_pool, v_pool, block_tables, lengths,
                              depth=depth, interpret=interpret)

"""Public flash-decode op with latency-aware depth selection.

``depth=None`` solves the pipeline depth from the KV-block `CoroSpec`
(`decode_attention.decode_spec`) via core.autotune — the VMEM cap comes
from the classified context bytes (shared online-softmax accumulators
don't multiply by depth), adaptive once transfer samples are recorded.
"""
from __future__ import annotations

from repro.core.machine import default_interpret
from repro.kernels.decode_attention.decode_attention import (
    flash_decode,
    paged_flash_decode,
)


def decode_attention(q, k_cache, v_cache, pos, *, blk: int = 128,
                     depth: int | None = None, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return flash_decode(q, k_cache, v_cache, pos, blk=blk, depth=depth,
                        interpret=interpret)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           depth: int | None = None,
                           interpret: bool | None = None):
    """Ragged-batch decode over a paged KV block pool (see
    `decode_attention.paged_flash_decode`). ``depth=None`` solves the
    pipeline depth from the page-tile `CoroSpec` via core.autotune."""
    interpret = default_interpret() if interpret is None else interpret
    return paged_flash_decode(q, k_pool, v_pool, block_tables, lengths,
                              depth=depth, interpret=interpret)


# -------- fallback twins (core.guard degradation path, ISSUE-10) --------
from repro.kernels import register_twin  # noqa: E402


def _flash_decode_twin(spec, pos, q, k_cache, v_cache):
    from repro.kernels.decode_attention.ref import decode_attention_ref
    return decode_attention_ref(q, k_cache, v_cache, pos[0])


def _paged_decode_twin(spec, bt_flat, lengths, q, k_pool, v_pool):
    # models.common.paged_decode_attention is the traceable masked twin the
    # serving engine already trusts; the seed paged_decode_attention_ref is
    # a host loop (int(lengths[r])) and cannot police traced calls.
    from repro.models.common import paged_decode_attention as paged_twin
    b = q.shape[0]
    m = bt_flat.shape[0] // b
    out = paged_twin(q[:, None], k_pool, v_pool, bt_flat.reshape(b, m),
                     lengths)
    return out[:, 0].astype(q.dtype)


register_twin("flash_decode", _flash_decode_twin)
register_twin("paged_decode", _paged_decode_twin)

"""Oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q: [B,H,D]; caches: [B,S,KH,D]; attend to positions <= pos.

    Returns [B,H,D].
    """
    b, h, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, d) * (d ** -0.5)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32))
    valid = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)

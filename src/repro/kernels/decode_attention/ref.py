"""Oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q: [B,H,D]; caches: [B,S,KH,D]; attend to positions <= pos.

    Returns [B,H,D].
    """
    b, h, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, d) * (d ** -0.5)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32))
    valid = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths):
    """Oracle for the paged kernel: gather each request's pages back into a
    dense cache, then run the dense oracle at that request's own position.

    q: [B,H,D]; pools: [NB, blk, KH, D]; block_tables: [B,M]; lengths: [B]
    (attend to positions < lengths[b]). Returns [B,H,D].
    """
    b = q.shape[0]
    blk, kh, d = k_pool.shape[1], k_pool.shape[2], k_pool.shape[3]
    m = block_tables.shape[1]
    outs = []
    for r in range(b):
        k = k_pool[block_tables[r]].reshape(1, m * blk, kh, d)
        v = v_pool[block_tables[r]].reshape(1, m * blk, kh, d)
        outs.append(decode_attention_ref(q[r:r + 1], k, v, int(lengths[r]) - 1))
    return jnp.concatenate(outs, axis=0)

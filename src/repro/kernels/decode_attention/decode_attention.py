"""Flash-decode kernel: KV-cache streaming declared as a `CoroSpec`.

One decode token attends over a long KV cache living in HBM ("far memory").
Each KV block is one coroutine: its k/v DMAs form an aset group on a slot
semaphore; while block i is in flight, blocks i-1..i-depth+1 are being
consumed by the online-softmax accumulator. This is the paper's pattern at
its purest — latency-bound streaming with O(1) compute per byte — and the
kernel the serving path uses on TPU (jnp twin: models.common.decode_attention).

The declaration carries the kernel's whole §III-B context: the k/v slot
buffers are private (x depth, derived by the builder), while the m/l/acc
online-softmax accumulators are *commutative* updates — classified SHARED,
allocated once regardless of depth — and q is a read-only resident counted
against the budget but materialized from the operand block. The pipeline is
`core.coro.coro_call` in fori mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import context as ctx_mod
from repro.core.coro import CoroSpec, LoadStream, coro_call

NEG_INF = -1e30


def decode_spec(blk: int, kh: int, g: int, d: int, dtype) -> CoroSpec:
    """KV block tile: k+v DMAs per slot; accumulators are depth-independent."""
    h = kh * g

    def kv_src(ref_name):
        def src(ctx, i):
            ref = getattr(ctx, ref_name)
            return ref.at[ctx.pids[0], pl.ds(i * blk, blk)]
        return src

    return CoroSpec(
        name="flash_decode",
        loads=(
            LoadStream("k", (blk, kh, d), dtype, src=kv_src("k_hbm")),
            LoadStream("v", (blk, kh, d), dtype, src=kv_src("v_hbm")),
        ),
        vars=(
            # online-softmax state: commutative (max / rescaled-sum)
            # reductions -> SHARED, one copy regardless of depth
            ctx_mod.var("m", (kh, g), jnp.float32,
                        carries_dependence=True, commutative=True),
            ctx_mod.var("l", (kh, g), jnp.float32,
                        carries_dependence=True, commutative=True),
            ctx_mod.var("acc", (kh, g, d), jnp.float32,
                        carries_dependence=True, commutative=True),
            # the scaled query: read-only resident (operand block + f32 copy
            # in the loop carry); accounting-only, no scratch of its own
            ctx_mod.VarSpec("q_f32", nbytes=4 * (h * d + kh * g * d),
                            read_only=True),
        ),
        flops_per_tile=float(4 * blk * h * d),  # qk + pv per block
    )


def flash_decode(q, k_cache, v_cache, pos, *, blk: int = 128,
                 depth: int | None = None, interpret: bool = True):
    """q: [B,H,D]; caches: [B,S,KH,D]; pos: scalar int32. Returns [B,H,D]."""
    bsz, h, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    assert s % blk == 0
    n_blocks = s // blk
    g = h // kh
    spec = decode_spec(blk, kh, g, d, k_cache.dtype)

    def prologue(ctx):
        # fresh accumulators for this batch element
        ctx.m[...] = jnp.full_like(ctx.m, NEG_INF)
        ctx.l[...] = jnp.zeros_like(ctx.l)
        ctx.acc[...] = jnp.zeros_like(ctx.acc)
        qv = ctx.q_in[0].reshape(kh, g, d).astype(jnp.float32) * (d ** -0.5)
        return (qv, ctx.pos[0])

    def body(ctx, i, slot, carry):
        qv, pos_v = carry
        k = ctx.k[slot].astype(jnp.float32)   # [blk, kh, d]
        v = ctx.v[slot].astype(jnp.float32)
        sc = jnp.einsum("kgd,bkd->kgb", qv, k)    # [kh, g, blk]
        kpos = i * blk + jax.lax.broadcasted_iota(jnp.int32, (1, 1, blk), 2)
        sc = jnp.where(kpos <= pos_v, sc, NEG_INF)
        m_new = jnp.maximum(ctx.m[...], sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(ctx.m[...] - m_new)
        ctx.l[...] = ctx.l[...] * corr + p.sum(axis=-1)
        ctx.acc[...] = (ctx.acc[...] * corr[..., None]
                        + jnp.einsum("kgb,bkd->kgd", p, v))
        ctx.m[...] = m_new
        return carry

    def epilogue(ctx, carry):
        out = ctx.acc[...] / jnp.maximum(ctx.l[...], 1e-30)[..., None]
        ctx.o[...] = out.reshape(1, kh * g, d).astype(ctx.o.dtype)

    return coro_call(
        spec, jnp.asarray([pos], jnp.int32), q, k_cache, v_cache,
        n_tiles=n_blocks, depth=depth, body=body,
        prologue=prologue, epilogue=epilogue,
        arg_names=("pos", "q_in", "k_hbm", "v_hbm", "o"),
        grid=(bsz,),
        num_scalar_prefetch=1,
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, pos_ref: (b, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b, pos_ref: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, d), q.dtype),
        interpret=interpret,
    )


# ----------------------------------------------------------- paged variant


def paged_decode_spec(blk: int, kh: int, g: int, d: int, dtype,
                      max_blocks: int) -> CoroSpec:
    """One KV *page* = one coroutine tile, fetched through the block table.

    The serving engine's pager scatters each request's cache across a shared
    HBM block pool; the LoadStream src is gather-indexed — the tile's DMA
    source is `pool[block_tables[b, i]]`, a data-dependent page id read from
    scalar-prefetch memory (the paper's indirectly addressed aload). Context
    is identical to the dense `decode_spec`: slots are private, the
    online-softmax accumulators are commutative -> SHARED, so every request
    in a round rides one pipeline at one solved depth.
    """
    h = kh * g

    def kv_src(ref_name):
        def src(ctx, i):
            ref = getattr(ctx, ref_name)
            bid = ctx.bt[ctx.pids[0] * max_blocks + i]
            return ref.at[pl.ds(bid, 1)]
        return src

    return CoroSpec(
        name="paged_decode",
        loads=(
            LoadStream("k", (1, blk, kh, d), dtype, src=kv_src("k_pool")),
            LoadStream("v", (1, blk, kh, d), dtype, src=kv_src("v_pool")),
        ),
        vars=(
            ctx_mod.var("m", (kh, g), jnp.float32,
                        carries_dependence=True, commutative=True),
            ctx_mod.var("l", (kh, g), jnp.float32,
                        carries_dependence=True, commutative=True),
            ctx_mod.var("acc", (kh, g, d), jnp.float32,
                        carries_dependence=True, commutative=True),
            ctx_mod.VarSpec("q_f32", nbytes=4 * (h * d + kh * g * d),
                            read_only=True),
        ),
        flops_per_tile=float(4 * blk * h * d),
    )


def paged_flash_decode(q, k_pool, v_pool, block_tables, lengths, *,
                       depth: int | None = None, interpret: bool = True):
    """Flash-decode over a paged KV pool with ragged per-request lengths.

    q: [B,H,D]; k_pool/v_pool: [NB, blk, KH, D]; block_tables: [B, M] int32
    (pad with the reserved garbage block 0); lengths: [B] int32 — request b
    attends key positions < lengths[b]. Returns [B,H,D]; rows with
    lengths == 0 are garbage (round padding slots).

    Every request walks the same M tiles (tail pages fully masked), so one
    `coro_call` at one solved depth serves the whole ragged round — the
    block table only redirects each tile's DMA source.
    """
    bsz, h, d = q.shape
    blk, kh = k_pool.shape[1], k_pool.shape[2]
    max_blocks = block_tables.shape[1]
    g = h // kh
    spec = paged_decode_spec(blk, kh, g, d, k_pool.dtype, max_blocks)

    def prologue(ctx):
        ctx.m[...] = jnp.full_like(ctx.m, NEG_INF)
        ctx.l[...] = jnp.zeros_like(ctx.l)
        ctx.acc[...] = jnp.zeros_like(ctx.acc)
        qv = ctx.q_in[0].reshape(kh, g, d).astype(jnp.float32) * (d ** -0.5)
        return (qv, ctx.lens[ctx.pids[0]])

    def body(ctx, i, slot, carry):
        qv, len_v = carry
        k = ctx.k[slot, 0].astype(jnp.float32)   # [blk, kh, d]
        v = ctx.v[slot, 0].astype(jnp.float32)
        sc = jnp.einsum("kgd,bkd->kgb", qv, k)    # [kh, g, blk]
        kpos = i * blk + jax.lax.broadcasted_iota(jnp.int32, (1, 1, blk), 2)
        sc = jnp.where(kpos < len_v, sc, NEG_INF)
        m_new = jnp.maximum(ctx.m[...], sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(ctx.m[...] - m_new)
        ctx.l[...] = ctx.l[...] * corr + p.sum(axis=-1)
        ctx.acc[...] = (ctx.acc[...] * corr[..., None]
                        + jnp.einsum("kgb,bkd->kgd", p, v))
        ctx.m[...] = m_new
        return carry

    def epilogue(ctx, carry):
        out = ctx.acc[...] / jnp.maximum(ctx.l[...], 1e-30)[..., None]
        ctx.o[...] = out.reshape(1, kh * g, d).astype(ctx.o.dtype)

    return coro_call(
        spec,
        jnp.asarray(block_tables, jnp.int32).reshape(-1),
        jnp.asarray(lengths, jnp.int32),
        q, k_pool, v_pool,
        n_tiles=max_blocks, depth=depth, body=body,
        prologue=prologue, epilogue=epilogue,
        arg_names=("bt", "lens", "q_in", "k_pool", "v_pool", "o"),
        grid=(bsz,),
        num_scalar_prefetch=2,
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, bt_ref, lens_ref: (b, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b, bt_ref, lens_ref: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, d), q.dtype),
        interpret=interpret,
    )

"""Flash-decode kernel: KV-cache streaming with the coroutine pipeline.

One decode token attends over a long KV cache living in HBM ("far memory").
Each KV block is one coroutine: its k/v DMAs form an aset group on a slot
semaphore; while block i is in flight, blocks i-1..i-depth+1 are being
consumed by the online-softmax accumulator. This is the paper's pattern at
its purest — latency-bound streaming with O(1) compute per byte — and the
kernel the serving path uses on TPU (jnp twin: models.common.decode_attention).
The pipeline schedule is `core.coro.coro_loop` in fori mode; only the
issue/wait/consume callbacks are kernel-specific.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import autotune
from repro.core.coro import coro_loop

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, k_slots, v_slots,
                   sems, m_s, l_s, acc_s, *, depth: int, blk: int,
                   n_blocks: int, kh: int, g: int, d: int):
    b = pl.program_id(0)
    pos = pos_ref[0]

    def issue(blk_i, slot):
        start = blk_i * blk
        pltpu.make_async_copy(k_ref.at[b, pl.ds(start, blk)], k_slots.at[slot],
                              sems.at[slot]).start()
        pltpu.make_async_copy(v_ref.at[b, pl.ds(start, blk)], v_slots.at[slot],
                              sems.at[slot]).start()

    def wait(blk_i, slot):
        pltpu.make_async_copy(k_slots.at[slot], k_slots.at[slot],
                              sems.at[slot]).wait()
        pltpu.make_async_copy(v_slots.at[slot], v_slots.at[slot],
                              sems.at[slot]).wait()

    # fresh accumulators for this batch element
    m_s[...] = jnp.full_like(m_s, NEG_INF)
    l_s[...] = jnp.zeros_like(l_s)
    acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].reshape(kh, g, d).astype(jnp.float32) * (d ** -0.5)

    def consume(i, slot, carry):
        k = k_slots[slot].astype(jnp.float32)   # [blk, kh, d]
        v = v_slots[slot].astype(jnp.float32)
        s = jnp.einsum("kgd,bkd->kgb", q, k)    # [kh, g, blk]
        kpos = i * blk + jax.lax.broadcasted_iota(jnp.int32, (1, 1, blk), 2)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_new = jnp.maximum(m_s[...], s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_s[...] - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=-1)
        acc_s[...] = acc_s[...] * corr[..., None] + jnp.einsum("kgb,bkd->kgd", p, v)
        m_s[...] = m_new
        return carry

    coro_loop(n_blocks, depth, issue, consume, wait)
    out = acc_s[...] / jnp.maximum(l_s[...], 1e-30)[..., None]
    o_ref[...] = out.reshape(1, kh * g, d).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, pos, *, blk: int = 128,
                 depth: int | None = None, interpret: bool = True):
    """q: [B,H,D]; caches: [B,S,KH,D]; pos: scalar int32. Returns [B,H,D]."""
    bsz, h, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    assert s % blk == 0
    n_blocks = s // blk
    g = h // kh
    if depth is None:
        depth = autotune.choose_depth(
            autotune.profile_decode(blk, kh, g, d, k_cache.dtype.itemsize),
            kernel="flash_decode")
    depth = min(depth, n_blocks)

    kernel = functools.partial(
        _decode_kernel, depth=depth, blk=blk, n_blocks=n_blocks,
        kh=kh, g=g, d=d,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, pos_ref: (b, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b, pos_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((depth, blk, kh, d), k_cache.dtype),
            pltpu.VMEM((depth, blk, kh, d), v_cache.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.VMEM((kh, g), jnp.float32),
            pltpu.VMEM((kh, g), jnp.float32),
            pltpu.VMEM((kh, g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, h, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray([pos], jnp.int32), q, k_cache, v_cache)

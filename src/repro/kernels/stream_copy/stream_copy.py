"""STREAM triad with an explicit decoupled load/store pipeline.

The bandwidth-bound end of the paper's benchmark suite (Table II). Unlike the
gather kernels, every request is a maximal coarse-grained span (the paper's
§III-C case 1 — unit-stride loops coalesce perfectly), so the pipeline
measures pure issue/consume overlap: tiles of b and c stream in as one aset
group of two span DMAs per slot while a-tiles stream back out. The rotation
is `core.coro.coro_loop` in grid mode; the store pipeline (drain previous
store, compute, start new store) lives in the consume callback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import autotune
from repro.core.coro import coro_loop


def _triad_kernel(s_ref, b_ref, c_ref, a_ref, b_slots, c_slots, a_slots,
                  load_sems, store_sems, *, depth: int, rows: int, n_tiles: int):
    i = pl.program_id(0)

    def issue(tile, slot):
        start = tile * rows
        pltpu.make_async_copy(b_ref.at[pl.ds(start, rows)], b_slots.at[slot],
                              load_sems.at[slot]).start()
        pltpu.make_async_copy(c_ref.at[pl.ds(start, rows)], c_slots.at[slot],
                              load_sems.at[slot]).start()

    def wait_loads(tile, slot):
        pltpu.make_async_copy(b_slots.at[slot], b_slots.at[slot],
                              load_sems.at[slot]).wait()
        pltpu.make_async_copy(c_slots.at[slot], c_slots.at[slot],
                              load_sems.at[slot]).wait()

    def wait_store(slot):
        pltpu.make_async_copy(a_slots.at[slot], a_slots.at[slot],
                              store_sems.at[slot]).wait()

    def consume(tile, slot, carry):
        @pl.when(tile >= depth)
        def _():
            wait_store(slot)

        a_slots[slot] = b_slots[slot] + s_ref[0] * c_slots[slot]
        pltpu.make_async_copy(a_slots.at[slot],
                              a_ref.at[pl.ds(tile * rows, rows)],
                              store_sems.at[slot]).start()
        return carry

    coro_loop(n_tiles, depth, issue, consume, wait_loads, grid_step=i)

    @pl.when(i == n_tiles - 1)
    def _():
        for s in range(min(depth, n_tiles)):
            wait_store(s)


def triad(b, c, scalar, *, rows: int = 128, depth: int | None = None,
          interpret: bool = True):
    """a = b + scalar*c over [N, d] arrays, N a multiple of `rows`."""
    n, d = b.shape
    assert n % rows == 0
    n_tiles = n // rows
    if depth is None:
        depth = autotune.choose_depth(
            autotune.profile_triad(rows, d, b.dtype.itemsize),
            kernel="stream_triad")
    depth = min(depth, n_tiles)
    kernel = functools.partial(_triad_kernel, depth=depth, rows=rows,
                               n_tiles=n_tiles)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,   # scalar in SMEM
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((depth, rows, d), b.dtype),
            pltpu.VMEM((depth, rows, d), b.dtype),
            pltpu.VMEM((depth, rows, d), b.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), b.dtype),
        interpret=interpret,
    )(jnp.asarray([scalar], b.dtype), b, c)

"""STREAM triad declared as a `CoroSpec`: decoupled load + store pipeline.

The bandwidth-bound end of the paper's benchmark suite (Table II). Unlike
the gather kernels, every request is a maximal coarse-grained span (the
paper's §III-C case 1 — unit-stride loops coalesce perfectly), so the
pipeline measures pure issue/consume overlap: tiles of b and c stream in as
two span `LoadStream`s per slot while a-tiles stream back out through a
`StoreStream`. The drain-previous-store / epilogue-drain plumbing is the
substrate's shared store path (`core.coro.coro_pipeline`) — the same code
coro_scatter_add rides — leaving the kernel a three-stream declaration and
a one-line body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coro import CoroSpec, LoadStream, StoreStream, coro_call


def triad_spec(rows: int, d: int, dtype) -> CoroSpec:
    """STREAM tile: two span loads plus one span store per slot."""
    return CoroSpec(
        name="stream_triad",
        loads=(
            LoadStream("bs", (rows, d), dtype,
                       src=lambda ctx, t: ctx.b.at[pl.ds(t * rows, rows)]),
            LoadStream("cs", (rows, d), dtype,
                       src=lambda ctx, t: ctx.c.at[pl.ds(t * rows, rows)]),
        ),
        stores=(
            StoreStream("as_", (rows, d), dtype,
                        dst=lambda ctx, t: ctx.a.at[pl.ds(t * rows, rows)]),
        ),
        flops_per_tile=float(2 * rows * d),  # fma per element
    )


def triad(b, c, scalar, *, rows: int = 128, depth: int | None = None,
          interpret: bool = True):
    """a = b + scalar*c over [N, d] arrays, N a multiple of `rows`."""
    n, d = b.shape
    assert n % rows == 0
    n_tiles = n // rows
    spec = triad_spec(rows, d, b.dtype)

    def body(ctx, t, slot, carry):
        ctx.as_[slot] = ctx.bs[slot] + ctx.s[0] * ctx.cs[slot]
        return carry

    return coro_call(
        spec, jnp.asarray([scalar], b.dtype), b, c,
        n_tiles=n_tiles, depth=depth, body=body,
        arg_names=("s", "b", "c", "a"),
        grid=(n_tiles,), drive_axis=0,
        num_scalar_prefetch=1,   # scalar in SMEM
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((n, d), b.dtype),
        interpret=interpret,
    )

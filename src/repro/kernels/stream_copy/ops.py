"""Public STREAM-triad op.

``depth=None`` solves the pipeline depth from the triad tile's
`TileProfile` via core.autotune (= `schedule.solve_depth` until transfer
samples are recorded).
"""
from __future__ import annotations

import jax

from repro.kernels.stream_copy.stream_copy import triad


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stream_triad(b, c, scalar, *, rows: int = 128, depth: int | None = None,
                 interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return triad(b, c, scalar, rows=rows, depth=depth, interpret=interpret)

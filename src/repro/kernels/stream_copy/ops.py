"""Public STREAM-triad op.

``depth=None`` solves the pipeline depth from the declared `CoroSpec`
(`stream_copy.triad_spec`) via core.autotune. The store side rides the
substrate's shared `StoreStream` drain path (the same code as
coro_scatter_add's RMW pipeline).
"""
from __future__ import annotations

from repro.core.machine import default_interpret
from repro.kernels.stream_copy.stream_copy import triad


def stream_triad(b, c, scalar, *, rows: int = 128, depth: int | None = None,
                 interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return triad(b, c, scalar, rows=rows, depth=depth, interpret=interpret)


# -------- fallback twin (core.guard degradation path, ISSUE-10) --------
# Adapter signature: (spec, *coro_call operands) -> pallas output structure.
from repro.kernels import register_twin  # noqa: E402


def _triad_twin(spec, s, b, c):
    from repro.kernels.stream_copy.ref import triad_ref
    return triad_ref(b, c, s[0])


register_twin("stream_triad", _triad_twin)

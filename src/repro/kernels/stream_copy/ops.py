"""Public STREAM-triad op."""
from __future__ import annotations

import jax

from repro.kernels.stream_copy.stream_copy import triad


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stream_triad(b, c, scalar, *, rows: int = 128, depth: int = 4,
                 interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return triad(b, c, scalar, rows=rows, depth=depth, interpret=interpret)

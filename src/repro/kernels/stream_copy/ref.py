"""Oracle for the STREAM-triad coroutine kernel."""


def triad_ref(b, c, scalar):
    """a = b + scalar * c (McCalpin STREAM triad)."""
    return b + scalar * c

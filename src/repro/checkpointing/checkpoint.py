"""Sharded, atomic, async checkpointing with elastic restore.

Layout: <dir>/step_<n>/ {meta.json, arrays.npz} committed via tmp-dir rename
(a partially written checkpoint is never visible). `save_async` runs the
serialization off-thread so the train loop keeps stepping. On restore, arrays
are placed with whatever shardings the *new* mesh prescribes — world-size
changes (elastic restart after node loss) just re-shard the same logical
arrays.

In a real multi-host deployment each process writes its address-able shards
and meta.json carries the global shape/sharding index; in this single-
controller container the full logical arrays are written.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save(state, ckpt_dir, step: int, *, keep: int = 3) -> Path:
    """Blocking atomic save. Returns the committed directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp_step_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "time": time.time(),
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    final = ckpt_dir / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Fire-and-forget saves; at most one in flight (newer preempts queueing)."""

    def __init__(self, ckpt_dir, *, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, state, step: int):
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # snapshot before mutation

        def work():
            save(host_state, self.ckpt_dir, step, keep=self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def restore(ckpt_dir, template, *, step: Optional[int] = None,
            shardings=None) -> Any:
    """Restore into `template`'s tree structure; re-shard for the current mesh.

    `shardings` (optional pytree of NamedSharding matching the template)
    re-lays arrays out on a possibly different mesh — elastic restart.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    leaves, treedef = _flatten(template)
    assert len(leaves) == len(data.files), "leaf count mismatch (arch changed?)"
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    state = jax.tree.unflatten(treedef, new_leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
            state, shardings,
        )
    return state


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(ckpt_dir.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)

"""Span tracer: ring-buffered structured tracing with Perfetto-ready export.

Every layer of the stack feeds one process-wide tracer (`get_tracer()`):
the serving engine emits request-lifecycle spans (admit -> prefix lookup ->
prefill chunks -> decode rounds -> finish, with instant events for COW
forks, cache evictions, and preemptions), `core.coro.coro_call` emits one
span per launched pipeline carrying depth / n_tiles / context-bytes
attributes, and the dense drive loop in `launch.serve` emits per-step round
spans. `export(path)` writes Chrome trace-event JSON that opens directly in
Perfetto (https://ui.perfetto.dev) or chrome://tracing.

Design constraints (ISSUE-8):

* zero dependencies - events are plain dicts in a `collections.deque` ring
  (default 65536 events; the oldest fall off, `dropped` counts them), so a
  long-lived serving process never grows without bound.
* true no-op when disabled - ``REPRO_TELEMETRY=0`` (the same switch
  `core.autotune` honours) swaps the module-level singleton for
  `NULL_TRACER`, whose methods do nothing and whose `span()` returns one
  shared context-manager instance. Hot loops fetch the tracer once and call
  through it unconditionally: the disabled path has no per-call branching
  and allocates no event objects (asserted in tests/test_obs.py).

Event vocabulary (Chrome trace-event phases):

  "X" complete span   - span(name, ...) context manager / complete(...)
  "i" instant event   - instant(name, ...); thread-scoped ("s": "t").
                        The serving failure model (ISSUE-9) emits its own
                        vocabulary here: "shed", "cancel", "stall",
                        "step_fault", "quarantine", "latency_spike", and
                        "run_stalled", alongside the original "admit" /
                        "cow_fork" / "cache_evict" / "preempt" events.
  "b"/"e" async pair  - begin_async/end_async(name, id): spans that outlive
                        one call frame (a request's whole lifetime)

Tracks: `pid` is always 1 (one process); `tid` picks the Perfetto track —
`TID_ENGINE` (0) for scheduler/engine rounds, `TID_KERNEL` (1) for
coroutine pipelines, `TID_REQUEST_BASE + rid` for per-request lifecycle
spans so each request renders as its own row.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Any, Dict, Optional

__all__ = [
    "NULL_TRACER",
    "TID_ENGINE",
    "TID_KERNEL",
    "TID_REQUEST_BASE",
    "Tracer",
    "enabled",
    "get_tracer",
    "reset",
    "set_tracing",
]

TELEMETRY_ENV = "REPRO_TELEMETRY"
DEFAULT_CAPACITY = 65536
PID = 1

TID_ENGINE = 0        # scheduler rounds, decode rounds, prefill chunks
TID_KERNEL = 1        # coroutine pipelines (coro_call / engine decode)
TID_REQUEST_BASE = 64  # request rid r renders on track TID_REQUEST_BASE + r


class _Span:
    """Context manager emitting one "X" complete event on exit."""

    __slots__ = ("_tracer", "_name", "_tid", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, tid: int,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self._name = name
        self._tid = tid
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer.now_us()
        return self

    def __exit__(self, *exc) -> None:
        t = self._tracer
        t._emit({"name": self._name, "cat": "repro", "ph": "X",
                 "ts": self._t0, "dur": t.now_us() - self._t0,
                 "pid": PID, "tid": self._tid,
                 "args": self._args or {}})


class Tracer:
    """Ring-buffered event collector with Chrome trace-event export."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.events: deque = deque(maxlen=capacity)
        self.dropped = 0
        self._epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------- clock

    def now_us(self) -> float:
        """Microseconds since this tracer's epoch (Chrome `ts` unit)."""
        return (time.perf_counter_ns() - self._epoch_ns) / 1e3

    # ------------------------------------------------------------ record

    def _emit(self, ev: Dict[str, Any]) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def span(self, name: str, tid: int = TID_ENGINE, **args) -> _Span:
        """``with tracer.span("decode_round", width=8): ...`` — one "X"
        complete event covering the block, attributes in `args`."""
        return _Span(self, name, tid, args or None)

    def complete(self, name: str, start_us: float, dur_us: float,
                 tid: int = TID_ENGINE, **args) -> None:
        """Emit an "X" span with explicit timing (for already-measured
        intervals: the pipeline wall clock `coro_call` observed)."""
        self._emit({"name": name, "cat": "repro", "ph": "X",
                    "ts": start_us, "dur": max(dur_us, 0.0),
                    "pid": PID, "tid": tid, "args": args})

    def instant(self, name: str, tid: int = TID_ENGINE, **args) -> None:
        """Thread-scoped instant event (COW fork, eviction, preemption)."""
        self._emit({"name": name, "cat": "repro", "ph": "i", "s": "t",
                    "ts": self.now_us(), "pid": PID, "tid": tid,
                    "args": args})

    def begin_async(self, name: str, aid: int, tid: int = TID_ENGINE,
                    **args) -> None:
        """Open an async span (paired by (`name`, `aid`) with end_async)."""
        self._emit({"name": name, "cat": "repro", "ph": "b", "id": int(aid),
                    "ts": self.now_us(), "pid": PID, "tid": tid,
                    "args": args})

    def end_async(self, name: str, aid: int, tid: int = TID_ENGINE,
                  **args) -> None:
        self._emit({"name": name, "cat": "repro", "ph": "e", "id": int(aid),
                    "ts": self.now_us(), "pid": PID, "tid": tid,
                    "args": args})

    # ------------------------------------------------------------ export

    def to_dict(self) -> Dict[str, Any]:
        """The Chrome trace-event container Perfetto opens directly."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs.trace",
                              "dropped_events": self.dropped}}

    def export(self, path: str) -> str:
        """Write the trace as JSON; returns `path`."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0


class _NullSpan:
    """The one shared do-nothing context manager the null tracer hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


class NullTracer:
    """API-compatible no-op: every method returns immediately, `span()`
    returns one module-lifetime `_NullSpan`, and there is no event storage
    at all — the ``REPRO_TELEMETRY=0`` fast path."""

    __slots__ = ()

    events: tuple = ()
    dropped: int = 0

    _SPAN = _NullSpan()

    def now_us(self) -> float:
        return 0.0

    def span(self, name: str, tid: int = TID_ENGINE, **args) -> _NullSpan:
        return self._SPAN

    def complete(self, name: str, start_us: float, dur_us: float,
                 tid: int = TID_ENGINE, **args) -> None:
        pass

    def instant(self, name: str, tid: int = TID_ENGINE, **args) -> None:
        pass

    def begin_async(self, name: str, aid: int, tid: int = TID_ENGINE,
                    **args) -> None:
        pass

    def end_async(self, name: str, aid: int, tid: int = TID_ENGINE,
                  **args) -> None:
        pass

    def to_dict(self) -> Dict[str, Any]:
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs.trace",
                              "dropped_events": 0}}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()


def _env_enabled() -> bool:
    return os.environ.get(TELEMETRY_ENV, "1") not in ("0", "off")


_tracer: Any = Tracer() if _env_enabled() else NULL_TRACER


def get_tracer():
    """The process-wide tracer (or `NULL_TRACER` when tracing is off).
    Fetch once per scope and call through it — no enabled() checks needed
    on the hot path."""
    return _tracer


def enabled() -> bool:
    return _tracer is not NULL_TRACER


def set_tracing(on: bool) -> None:
    """Process-wide switch. Turning tracing on installs a FRESH ring (the
    previous tracer's events are gone); turning it off installs the null
    singleton so in-flight references degrade to no-ops on their next call."""
    global _tracer
    if on:
        if _tracer is NULL_TRACER:
            _tracer = Tracer()
    else:
        _tracer = NULL_TRACER


def reset() -> None:
    """Re-resolve from ``REPRO_TELEMETRY`` with an empty ring (the test
    fixture's isolation hook)."""
    global _tracer
    _tracer = Tracer() if _env_enabled() else NULL_TRACER

"""Unified observability layer (ISSUE-8): tracing, metrics, stall breakdown.

Three pieces, one switch (``REPRO_TELEMETRY=0`` turns all of it into
module-level null objects with no per-call branching on hot paths):

  obs.trace     - ring-buffered span tracer, Chrome trace-event JSON export
                  (opens in Perfetto); request-lifecycle spans, coroutine
                  pipeline spans, COW/evict/preempt instant events
  obs.metrics   - named counters/gauges/histograms, JSON + Prometheus text
                  export, and the ONE percentile/latency_report
                  implementation every layer shares
  obs.breakdown - Fig. 14-style attribution of observed wall time to
                  compute vs. exposed transfer vs. scheduling gap, driven
                  by the `MachineModel` solve + live telemetry samples

See DESIGN.md §2.5 for the span taxonomy, metric names, and a worked
example of reading a paged-serve trace in Perfetto.
"""
from __future__ import annotations

from repro.obs import breakdown, metrics, trace
from repro.obs.breakdown import attribute, stall_breakdown
from repro.obs.metrics import (
    NULL_REGISTRY,
    MetricsRegistry,
    default_registry,
    latency_report,
    new_registry,
    percentile,
)
from repro.obs.trace import NULL_TRACER, Tracer, get_tracer

__all__ = [
    "MetricsRegistry",
    "NULL_REGISTRY",
    "NULL_TRACER",
    "Tracer",
    "attribute",
    "breakdown",
    "default_registry",
    "enabled",
    "get_tracer",
    "latency_report",
    "metrics",
    "new_registry",
    "percentile",
    "reset",
    "set_enabled",
    "stall_breakdown",
    "trace",
]


def enabled() -> bool:
    """True when BOTH the tracer and the registry are live."""
    return trace.enabled() and metrics.metrics_enabled()


def set_enabled(on: bool) -> None:
    """Flip tracing and metrics together (the runtime analogue of
    ``REPRO_TELEMETRY``; `core.autotune.set_telemetry` is the third,
    independent switch for the depth-feedback store)."""
    trace.set_tracing(on)
    metrics.set_metrics(on)


def reset() -> None:
    """Re-resolve both subsystems from the environment with empty state
    (tests/conftest.py calls this between tests)."""
    trace.reset()
    metrics.reset()

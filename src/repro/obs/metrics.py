"""Metrics registry: named counters, gauges, and fixed-bucket histograms.

The other half of the observability layer (ISSUE-8; `obs/trace.py` is the
span side): one `MetricsRegistry` holds every named metric a component
reports, exports a JSON `snapshot()` and a Prometheus text exposition
(`prometheus_text()`), and hosts read-only *views* — callables folded into
the snapshot at read time (e.g. `core.autotune.telemetry_summary` appears
under the default registry's ``autotune`` view, so one snapshot covers both
the engine's counters and the kernel feedback loop).

This module is also the single home of percentile math: `percentile()` and
`latency_report()` replace the copies that used to live in
`core.autotune._percentile` and `serve.engine.latency_report` — every
p50/p99 the repo reports comes from here (ISSUE-8 satellite).

Disabled path: like the tracer, ``REPRO_TELEMETRY=0`` swaps the default
registry for `NULL_REGISTRY`, and `new_registry()` hands out the same null
object — its counters/gauges/histograms are shared no-op singletons, so an
instrumented hot loop costs a method call that immediately returns, with no
per-call branching and no sample storage.

Histograms keep (a) fixed-bucket counts for the Prometheus export and
(b) a bounded sample ring (`max_samples`, default 4096 — same spirit as
`core.autotune.MAX_SAMPLES_PER_KERNEL`) from which exact-rank percentiles
are computed, so `p50/p99` match what the old ad-hoc lists reported instead
of being bucket-quantised.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "DEFAULT_LATENCY_BUCKETS_S",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "default_registry",
    "latency_report",
    "metrics_enabled",
    "new_registry",
    "percentile",
    "reset",
    "set_metrics",
]

TELEMETRY_ENV = "REPRO_TELEMETRY"

# powers-of-~3 from 100us to 3s: wide enough for interpret-mode rounds and
# tight enough that real-TPU token latencies land in distinct buckets
DEFAULT_LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0)

MAX_SAMPLES = 4096


# ------------------------------------------------------------- percentiles


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile over raw samples (0 on empty input) — the
    ONE implementation every p50/p99 in the repo routes through."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    return ys[min(int(q * len(ys)), len(ys) - 1)]


def latency_report(samples_s: Sequence[float]) -> Dict[str, float]:
    """The one latency-stats dict every serving path reports: p50/p99/mean
    of a per-token latency sample list, in milliseconds. Shared by the
    paged engine (`stats`) and both engines in `launch.serve`."""
    if not samples_s:
        return {"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
    return {
        "p50_ms": round(percentile(samples_s, 0.50) * 1e3, 3),
        "p99_ms": round(percentile(samples_s, 0.99) * 1e3, 3),
        "mean_ms": round(sum(samples_s) / len(samples_s) * 1e3, 3),
    }


# ----------------------------------------------------------------- metrics


class Counter:
    """Monotonically increasing value (float so second-accumulators fit)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram plus a bounded raw-sample ring.

    `samples` is a plain list callers may read (and clear — the fairness
    test in tests/test_prefix_cache.py does); bucket counts and
    `count`/`sum` are cumulative and survive such clears.
    """

    __slots__ = ("name", "buckets", "bucket_counts", "count", "sum",
                 "samples", "max_samples")

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S,
                 max_samples: int = MAX_SAMPLES):
        self.name = name
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +inf tail
        self.count = 0
        self.sum = 0.0
        self.samples: List[float] = []
        self.max_samples = int(max_samples)

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        i = 0
        for i, edge in enumerate(self.buckets):
            if x <= edge:
                break
        else:
            i = len(self.buckets)
        self.bucket_counts[i] += 1
        xs = self.samples
        xs.append(x)
        if len(xs) > self.max_samples:
            del xs[: len(xs) - self.max_samples]

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def report(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": round(self.sum, 6),
            "mean": round(self.sum / self.count, 6) if self.count else 0.0,
            "p50": round(self.percentile(0.50), 6),
            "p99": round(self.percentile(0.99), 6),
        }


class MetricsRegistry:
    """Named metrics + read-time views, with JSON and Prometheus export."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._views: Dict[str, Callable[[], Any]] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S,
                  ) -> Histogram:
        return self._get(name, Histogram, buckets)

    def view(self, name: str, fn: Callable[[], Any]) -> None:
        """Register a callable whose result is folded into `snapshot()`
        under `name` at read time (a registry *view*, not a stored value)."""
        self._views[name] = fn

    # ------------------------------------------------------------ export

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.report()
        for name, fn in sorted(self._views.items()):
            out[name] = fn()
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition (metric names '.'->'_')."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            pname = name.replace(".", "_").replace("-", "_")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value:g}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {m.value:g}")
            else:
                lines.append(f"# TYPE {pname} histogram")
                acc = 0
                for edge, n in zip(m.buckets, m.bucket_counts):
                    acc += n
                    lines.append(f'{pname}_bucket{{le="{edge:g}"}} {acc}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pname}_sum {m.sum:g}")
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self) -> None:
        self._metrics.clear()
        self._views.clear()


# --------------------------------------------------------------- null path


class _NullCounter:
    __slots__ = ()
    name = "null"
    value = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    name = "null"
    value = 0.0

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    name = "null"
    buckets: tuple = ()
    bucket_counts: list = []
    count = 0
    sum = 0.0
    samples: list = []          # shared; observe() never appends

    def observe(self, x: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return 0.0

    def report(self) -> Dict[str, float]:
        return {"count": 0, "sum": 0.0, "mean": 0.0, "p50": 0.0, "p99": 0.0}


class NullRegistry:
    """No-op registry: shared metric singletons, empty exports."""

    __slots__ = ()

    _COUNTER = _NullCounter()
    _GAUGE = _NullGauge()
    _HISTOGRAM = _NullHistogram()

    def counter(self, name: str) -> _NullCounter:
        return self._COUNTER

    def gauge(self, name: str) -> _NullGauge:
        return self._GAUGE

    def histogram(self, name: str, buckets: Sequence[float] = (),
                  ) -> _NullHistogram:
        return self._HISTOGRAM

    def view(self, name: str, fn: Callable[[], Any]) -> None:
        pass

    def snapshot(self) -> Dict[str, Any]:
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def prometheus_text(self) -> str:
        return ""

    def clear(self) -> None:
        pass


NULL_REGISTRY = NullRegistry()


def _env_enabled() -> bool:
    return os.environ.get(TELEMETRY_ENV, "1") not in ("0", "off")


def _autotune_view() -> Dict[str, Any]:
    from repro.core import autotune  # local: autotune imports this module

    return autotune.telemetry_summary()


def _substrate_view() -> Dict[str, Any]:
    from repro.core import guard  # local: guard imports this module

    return guard.stats()


def _make_default() -> Any:
    if not _env_enabled():
        return NULL_REGISTRY
    reg = MetricsRegistry()
    reg.view("autotune", _autotune_view)
    reg.view("substrate", _substrate_view)
    return reg


_default: Any = _make_default()
_enabled: bool = _env_enabled()


def default_registry():
    """The process-wide registry (kernel_bench's `--json` metrics snapshot
    reads it; the autotune telemetry view lives here)."""
    return _default


def new_registry(enabled: Optional[bool] = None):
    """A fresh registry for a component instance (one per serving engine,
    so two engines in one process never mix counters) — or the shared
    `NULL_REGISTRY` when metrics are off."""
    on = _enabled if enabled is None else enabled
    return MetricsRegistry() if on else NULL_REGISTRY


def metrics_enabled() -> bool:
    return _enabled


def set_metrics(on: bool) -> None:
    """Process-wide switch; turning on installs a fresh default registry."""
    global _default, _enabled
    _enabled = bool(on)
    if on:
        if _default is NULL_REGISTRY:
            reg = MetricsRegistry()
            reg.view("autotune", _autotune_view)
            reg.view("substrate", _substrate_view)
            _default = reg
    else:
        _default = NULL_REGISTRY


def reset() -> None:
    """Re-resolve from ``REPRO_TELEMETRY`` with empty state (test isolation)."""
    global _default, _enabled
    _default = _make_default()
    _enabled = _env_enabled()

"""Stall-breakdown reporter: runtime reproduction of the paper's Fig. 14.

CoroAMU's evaluation attributes execution time to compute vs. decoupled
memory access vs. scheduling overhead (Fig. 14); `benchmarks/fig14_breakdown`
reproduces that figure from the cycle simulator. This module produces the
same *shape* of report for the live system: for each kernel the always-on
telemetry has samples for, it combines the `core.machine.MachineModel`
schedule solve with the observed per-tile wall time to say where the cycles
went.

Methodology (DESIGN.md §2.5): for a kernel with tile profile `p` running at
pipeline depth `d` on machine `m`, the model gives

  t_compute  = p.flops_per_tile / m.peak_flops
  t_transfer = p.tile_bytes / m.hbm_bw
  t_model    = max(t_compute, t_transfer,
                   (m.hbm_latency_s + t_transfer + t_compute) / d)

(`t_model` is `schedule.achieved_bandwidth`'s steady-state period: the
third term is the latency the pipeline failed to hide at depth `d`).
Observed per-tile wall time `w` (p50 of `core.autotune`'s transfer samples)
is then attributed greedily:

  compute  = min(t_compute, w)                     # the MXU/VPU's share
  transfer = min(max(t_model - t_compute, 0),      # modelled EXPOSED memory
                 w - compute)                      #   time (not hidden
                                                   #   under compute)
  gap      = w - compute - transfer                # scheduling/host residual

so compute + transfer + gap == w by construction (the acceptance criterion
"sums to round wall time within 10%" holds exactly, modulo rounding) and
`gap` isolates what neither the compute roofline nor the latency model
explains — jit dispatch, scheduler bookkeeping, interpret-mode overhead.

Surfaced via `core.autotune.telemetry_summary()` (per-kernel ``breakdown``
entries), `benchmarks/kernel_bench.py --json`, and the ``--trace`` runs'
companion reports.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.machine import MachineModel, get_machine
from repro.core.schedule import TileProfile, tile_compute_s, tile_transfer_s

__all__ = ["attribute", "stall_breakdown"]


def attribute(profile: TileProfile, depth: Optional[int],
              observed_tile_s: float, *,
              machine: Optional[MachineModel] = None) -> Dict[str, Any]:
    """Attribute one kernel's observed per-tile wall time (seconds) to
    compute / exposed transfer / scheduling gap. See module docstring for
    the exact split; all times reported in microseconds."""
    m = machine or get_machine()
    d = max(int(depth) if depth else 1, 1)
    tc = tile_compute_s(profile, machine=m)
    tt = tile_transfer_s(profile, machine=m)
    t_model = max(tc, tt, (m.hbm_latency_s + tt + tc) / d)
    w = max(float(observed_tile_s), 0.0)
    compute = min(tc, w)
    transfer = min(max(t_model - tc, 0.0), w - compute)
    gap = max(w - compute - transfer, 0.0)
    return {
        "depth": d,
        "observed_us": round(w * 1e6, 3),
        "modeled_us": round(t_model * 1e6, 3),
        "compute_us": round(compute * 1e6, 3),
        "transfer_us": round(transfer * 1e6, 3),
        "gap_us": round(gap * 1e6, 3),
        "compute_frac": round(compute / w, 4) if w else 0.0,
        "transfer_frac": round(transfer / w, 4) if w else 0.0,
        "gap_frac": round(gap / w, 4) if w else 0.0,
    }


def stall_breakdown(machine: Optional[MachineModel] = None) -> Dict[str, Any]:
    """Fig. 14-shaped report over every kernel the feedback store has both
    samples and a recorded tile profile for (the active machine's slice of
    `core.autotune`'s stores). Kernels observed without a profile (e.g. a
    drive loop that only calls `observe_pipeline`) are listed with their
    observed time entirely unattributed."""
    from repro.core import autotune  # local: autotune ties back into obs

    m = machine or get_machine()
    summ = autotune.telemetry_summary()
    out: Dict[str, Any] = {"machine": m.name, "kernels": {}}
    for kernel, entry in summ["kernels"].items():
        if not entry.get("samples"):
            continue
        bd = entry.get("breakdown")
        if bd is None:
            w_us = entry.get("p50_us", 0.0)
            bd = {"depth": entry.get("depth"), "observed_us": w_us,
                  "modeled_us": None, "compute_us": 0.0, "transfer_us": 0.0,
                  "gap_us": w_us, "unattributed": True}
        out["kernels"][kernel] = bd
    return out

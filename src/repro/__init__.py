"""CoroAMU on TPU: memory-driven coroutines as decoupled DMA pipelines.

Public API surface:
  repro.configs     - ArchConfig registry (--arch ids) + shape suites
  repro.models      - build_model(cfg, ctx): loss / prefill / decode_step
  repro.core        - the paper's contribution (coro engine, coalescing,
                      context classes, depth solver, evaluation model)
  repro.kernels     - Pallas TPU kernels (+ ops wrappers + jnp oracles)
  repro.runtime     - steps, layouts, train loop, fault tolerance
  repro.launch      - mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"

"""Mamba-2 SSD (state-space duality) block: chunked train path + O(1) decode.

The chunked SSD algorithm streams sequence chunks through a small recurrent
state — the same structure as the paper's coroutine pipeline (each chunk is an
in-flight tile; the inter-chunk state is the "sequential" variable class of
CoroAMU §III-B). kernels/ssd_scan implements the chunk loop with decoupled
DMA; this module is the jnp model path and the oracle.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec
from repro.models.common import rms_norm

# ----------------------------------------------------------------- SSD math


def ssd_sequential(x, dt, A, B, C, h0=None):
    """Reference recurrence. x:[b,s,h,p] dt:[b,s,h] A:[h] B,C:[b,s,n].

    h_t = h_{t-1} * exp(A*dt_t) + dt_t * x_t outer B_t ;  y_t = h_t . C_t
    Returns (y [b,s,h,p], h_final [b,h,p,n]).
    """
    b, s, nh, p = x.shape
    n = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, nh, p, n), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # [b,h,p], [b,h], [b,n], [b,n]
        decay = jnp.exp(dtt.astype(jnp.float32) * A)[..., None, None]
        h = h * decay + (dtt[..., None, None].astype(jnp.float32)
                         * xt[..., None].astype(jnp.float32)
                         * Bt[:, None, None, :].astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", h, Ct.astype(jnp.float32))
        return h, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1), B.swapaxes(0, 1), C.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), h


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None, unroll_heads: bool = False):
    """Chunked SSD (Mamba-2 Alg. 1, single B/C group). Same signature/result
    as ssd_sequential but O(s*chunk) attention-like work within chunks.

    The intra-chunk decay matrix is formed per-head (scan over heads) so the
    transient is [b,nc,q,k] instead of [b,nc,q,k,h]. `unroll_heads` switches
    the head loop to a Python loop (dry-run exact cost accounting)."""
    b, s, nh, p = x.shape
    n = B.shape[-1]
    if s % chunk != 0:
        return ssd_sequential(x, dt, A, B, C, h0)
    nc = s // chunk
    if h0 is None:
        h0 = jnp.zeros((b, nh, p, n), jnp.float32)

    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, nh, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, nh).astype(f32)
    Bc = B.reshape(b, nc, chunk, n).astype(f32)
    Cc = C.reshape(b, nc, chunk, n).astype(f32)

    dA = dtc * A  # [b,nc,q,h] (<= 0)
    cs = jnp.cumsum(dA, axis=2)
    total = cs[:, :, -1:, :]  # [b,nc,1,h]
    dtx = xc * dtc[..., None]  # [b,nc,q,h,p]

    # intra-chunk (attention-like) term, one head at a time
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # [b,nc,q,k]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None]

    def head_y(cs_h, dtx_h):
        # cs_h [b,nc,q], dtx_h [b,nc,k,p]
        seg = cs_h[:, :, :, None] - cs_h[:, :, None, :]
        L = jnp.where(causal, jnp.exp(seg), 0.0)
        return jnp.einsum("bcqk,bckp->bcqp", scores * L, dtx_h)

    if unroll_heads:
        y_intra = jnp.stack(
            [head_y(cs[..., h], dtx[..., h, :]) for h in range(nh)], axis=3
        )  # [b,nc,q,h,p]
    else:
        ys = jax.lax.map(
            lambda args: head_y(*args),
            (jnp.moveaxis(cs, -1, 0), jnp.moveaxis(dtx, -2, 0)),
        )  # [h,b,nc,q,p]
        y_intra = jnp.moveaxis(ys, 0, 3)

    # per-chunk input state contribution
    decay_to_end = jnp.exp(total - cs)  # [b,nc,q,h]
    s_chunk = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc, decay_to_end, dtx)

    # inter-chunk recurrence over nc
    def step(h, inp):  # h: [b,h,p,n]
        s_c, tot_c = inp  # [b,h,n,p], [b,h]
        h_out = h
        h = h * jnp.exp(tot_c)[..., None, None] + s_c.swapaxes(-1, -2)
        return h, h_out

    sc = s_chunk.transpose(1, 0, 2, 3, 4)           # [nc,b,h,n,p]
    tc = total[:, :, 0, :].transpose(1, 0, 2)       # [nc,b,h]
    h_fin, h_prevs = jax.lax.scan(lambda h, i: step(h, i), h0, (sc, tc))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)      # [b,nc,h,p,n]

    # inter-chunk output term
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(cs), h_prevs)

    y = (y_intra + y_inter).reshape(b, s, nh, p).astype(x.dtype)
    return y, h_fin


# ------------------------------------------------------------ block plumbing


def ssm_dims(cfg: ArchConfig) -> Dict[str, int]:
    di = cfg.d_inner
    n = cfg.ssm_state
    nh = cfg.ssm_heads
    return dict(
        di=di, n=n, nh=nh, p=cfg.ssm_head_dim,
        conv_dim=di + 2 * n,
        d_in_proj=2 * di + 2 * n + nh,
    )


def ssm_param_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = ssm_dims(cfg)
    dm = cfg.d_model
    common = {
        "A_log": ParamSpec((d["nh"],), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamSpec((d["nh"],), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((d["nh"],), ("ssm_heads",), init="ones"),
        "norm_w": ParamSpec((d["di"],), ("d_inner",), init="ones"),
    }
    if cfg.ssm_split_proj:
        # shard-aligned: x/z projected per (head, head_dim) so the SSD runs
        # head-dim tensor parallel with no cross-shard slicing (§Perf)
        nh, p, n = d["nh"], d["p"], d["n"]
        return {
            **common,
            "w_z": ParamSpec((dm, nh, p), ("embed", "ssm_heads", "head_dim"), init="fan_in"),
            "w_x": ParamSpec((dm, nh, p), ("embed", "ssm_heads", "head_dim"), init="fan_in"),
            "w_B": ParamSpec((dm, n), ("embed", "ssm_state"), init="fan_in"),
            "w_C": ParamSpec((dm, n), ("embed", "ssm_state"), init="fan_in"),
            "w_dt": ParamSpec((dm, nh), ("embed", "ssm_heads"), init="fan_in"),
            "conv_x": ParamSpec((cfg.conv_width, nh, p), ("width", "ssm_heads", "head_dim"), init="fan_in"),
            "conv_B": ParamSpec((cfg.conv_width, n), ("width", "ssm_state"), init="fan_in"),
            "conv_C": ParamSpec((cfg.conv_width, n), ("width", "ssm_state"), init="fan_in"),
            "conv_bx": ParamSpec((nh, p), ("ssm_heads", "head_dim"), init="zeros"),
            "conv_bB": ParamSpec((n,), ("ssm_state",), init="zeros"),
            "conv_bC": ParamSpec((n,), ("ssm_state",), init="zeros"),
            "out_proj": ParamSpec((nh, p, dm), ("ssm_heads", "head_dim", "embed"), init="fan_in"),
        }
    return {
        **common,
        "in_proj": ParamSpec((dm, d["d_in_proj"]), ("embed", "d_inner"), init="fan_in"),
        "conv_w": ParamSpec((cfg.conv_width, d["conv_dim"]), ("width", "conv_dim"), init="fan_in"),
        "conv_b": ParamSpec((d["conv_dim"],), ("conv_dim",), init="zeros"),
        "out_proj": ParamSpec((d["di"], dm), ("d_inner", "embed"), init="fan_in"),
    }


def _split_proj(zxbcdt, cfg: ArchConfig):
    d = ssm_dims(cfg)
    z = zxbcdt[..., : d["di"]]
    xBC = zxbcdt[..., d["di"]: d["di"] + d["conv_dim"]]
    dt = zxbcdt[..., d["di"] + d["conv_dim"]:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv along seq. xBC:[B,S,C], w:[W,C]."""
    width = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i: i + xBC.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return out + b[None, None, :]


def _forward_split(p, x, cfg: ArchConfig, h0=None, return_state=False):
    """Shard-aligned SSD forward (§Perf): per-piece projections + depthwise
    convs keep every tensor head-dim sharded; no cross-shard slicing."""
    d = ssm_dims(cfg)
    dt_ = x.dtype
    nh, pp, n = d["nh"], d["p"], d["n"]
    b, s, _ = x.shape
    z = jnp.einsum("bsd,dhp->bshp", x, p["w_z"].astype(dt_))
    xh = jnp.einsum("bsd,dhp->bshp", x, p["w_x"].astype(dt_))
    Bs = x @ p["w_B"].astype(dt_)
    Cs = x @ p["w_C"].astype(dt_)
    dt = x @ p["w_dt"].astype(dt_)

    def conv_h(u, w, bias):  # depthwise causal conv on [b,s,h,p]
        width = w.shape[0]
        pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0), (0, 0)))
        out = sum(pad[:, i: i + s] * w[i][None, None] for i in range(width))
        return out + bias[None, None]

    xh = jax.nn.silu(conv_h(xh, p["conv_x"].astype(dt_), p["conv_bx"].astype(dt_)))
    Bs = jax.nn.silu(_causal_conv(Bs, p["conv_B"].astype(dt_), p["conv_bB"].astype(dt_)))
    Cs = jax.nn.silu(_causal_conv(Cs, p["conv_C"].astype(dt_), p["conv_bC"].astype(dt_)))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_fin = ssd_chunked(xh, dt, A, Bs, Cs, cfg.ssm_chunk, h0,
                           unroll_heads=not cfg.scan_layers)
    y = y + p["D"].astype(y.dtype)[:, None] * xh
    g = (y * jax.nn.silu(z)).reshape(b, s, nh * pp)
    g = rms_norm(g, p["norm_w"], cfg.norm_eps).reshape(b, s, nh, pp)
    out = jnp.einsum("bshp,hpd->bsd", g, p["out_proj"].astype(dt_))
    if return_state:
        raise NotImplementedError(
            "ssm_split_proj is a training-layout optimization; decode/prefill "
            "cache handoff uses the joint in_proj layout")
    return out


def ssm_forward(p, x, cfg: ArchConfig, h0=None, conv0=None, return_state=False):
    """Full-sequence SSD block. x: [B,S,d_model] -> [B,S,d_model]."""
    if cfg.ssm_split_proj and "w_x" in p:
        # split path keeps its own conv handling; conv0/decode handoff uses
        # the joint layout (training/prefill-analysis path only)
        assert conv0 is None, "split-proj path is for full-sequence analysis"
        return _forward_split(p, x, cfg, h0, return_state)
    d = ssm_dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    if conv0 is not None:
        ext = jnp.concatenate([conv0.astype(xBC.dtype), xBC], axis=1)
        conv_out = _causal_conv(ext, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
        xBC = conv_out[:, conv0.shape[1]:]
    else:
        xBC = _causal_conv(xBC, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., : d["di"]]
    Bs = xBC[..., d["di"]: d["di"] + d["n"]]
    Cs = xBC[..., d["di"] + d["n"]:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(*xs.shape[:-1], d["nh"], d["p"])
    y, h_fin = ssd_chunked(xh, dt, A, Bs, Cs, cfg.ssm_chunk, h0,
                           unroll_heads=not cfg.scan_layers)
    y = y + p["D"].astype(y.dtype)[:, None] * xh
    y = y.reshape(*xs.shape)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        # conv state: last (W-1) pre-activation xBC inputs
        zx = _split_proj(zxbcdt, cfg)[1]
        if conv0 is not None:
            zx = jnp.concatenate([conv0.astype(zx.dtype), zx], axis=1)
        conv_state = zx[:, -(cfg.conv_width - 1):, :]
        return out, h_fin, conv_state
    return out


def ssm_decode(p, cache: Dict[str, jax.Array], x, cfg: ArchConfig):
    """One-token decode. x: [B,1,d_model]; cache: {"h":[B,H,P,N], "conv":[B,W-1,conv_dim]}."""
    d = ssm_dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    ext = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)
    conv_out = _causal_conv(ext, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    new_conv = ext[:, 1:, :]
    xBC = jax.nn.silu(conv_out[:, -1:, :])
    xs = xBC[..., : d["di"]]
    Bs = xBC[..., d["di"]: d["di"] + d["n"]][:, 0]
    Cs = xBC[..., d["di"] + d["n"]:][:, 0]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(xs.shape[0], d["nh"], d["p"])  # [B,H,P]
    h = cache["h"]
    decay = jnp.exp(dt * A)[..., None, None]
    h = h * decay + dt[..., None, None] * xh[..., None].astype(jnp.float32) \
        * Bs[:, None, None, :].astype(jnp.float32)
    y = jnp.einsum("bhpn,bn->bhp", h, Cs.astype(jnp.float32)).astype(x.dtype)
    y = y + p["D"].astype(y.dtype)[:, None] * xh
    y = y.reshape(x.shape[0], 1, d["di"])
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"h": h, "conv": new_conv.astype(x.dtype)}


def ssm_cache_shape(cfg: ArchConfig, batch: int) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    d = ssm_dims(cfg)
    return {
        "h": ((batch, d["nh"], d["p"], d["n"]), "float32"),
        "conv": ((batch, cfg.conv_width - 1, d["conv_dim"]), cfg.dtype),
    }

"""Parameter declaration: shapes + logical axes + initializers in one tree.

Models declare a pytree of ``ParamSpec``; the tree can then be materialized as
  * ShapeDtypeStructs (dry-run: no allocation),
  * real initialized arrays (tests / training),
  * PartitionSpecs / NamedShardings (via repro.sharding.ShardingCtx).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import ShardingCtx


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | fan_in
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract(tree):
    """ShapeDtypeStruct tree (for .lower / eval_shape; no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree, is_leaf=is_spec
    )


def initialize(rng, tree, *, on_host: bool = True):
    """Materialize real parameter arrays (CPU tests / examples)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, s in zip(keys, leaves):
        if s.init == "zeros":
            a = jnp.zeros(s.shape, s.dtype)
        elif s.init == "ones":
            a = jnp.ones(s.shape, s.dtype)
        else:
            scale = s.scale
            if s.init == "fan_in":
                fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
                scale = 1.0 / np.sqrt(max(fan_in, 1))
            a = (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(s.dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def partition_specs(tree, ctx: ShardingCtx):
    return jax.tree.map(lambda s: ctx.spec(s.axes, s.shape), tree, is_leaf=is_spec)


def shardings(tree, ctx: ShardingCtx):
    if ctx.mesh is None:
        return None
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, ctx.spec(s.axes, s.shape)),
        tree,
        is_leaf=is_spec,
    )


def count(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(tree, is_leaf=is_spec))

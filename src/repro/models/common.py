"""Layer library: norms, RoPE, GQA attention (naive / chunked / flash-decode),
SwiGLU MLP, embeddings.

Attention comes in three implementations:
  * naive    — materializes [B,H,Sq,Sk] scores. Paper-faithful baseline.
  * chunked  — online-softmax scan over KV chunks (flash-style in jnp). This is
               the jnp twin of the coroutine pipeline: each KV chunk is one
               in-flight "coroutine" tile; see kernels/decode_attention for the
               Pallas version with real decoupled DMA.
  * flash-decode (shard_map) — sequence-sharded KV cache (context parallelism)
               with partial-softmax psum combine over the model axis.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import ShardingCtx, shard_map

# --------------------------------------------------------------------- basics


def _rms(x, eps):
    x32 = x.astype(jnp.float32)
    return x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)


def rms_norm(x, w, eps: float = 1e-5):
    return (_rms(x, eps) * w.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [S] or [B, S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, D/2]
    # broadcast over heads axis
    angles = angles[..., None, :]  # [..., S, 1, D/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_pos_emb(seq: int, d_model: int, dtype):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10_000.0) * dim / d_model)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate.astype(x.dtype)) * (x @ w_up.astype(x.dtype))
    return h @ w_down.astype(x.dtype)


def embed_lookup(table, tokens):
    """Embedding gather — the GUPS/hash-join access pattern of the paper.

    On TPU the kernels/coro_gather pipeline implements this with decoupled
    DMA; the jnp `take` is the oracle-equivalent used on CPU and in dry-runs.
    """
    return jnp.take(table, tokens, axis=0)


# ------------------------------------------------------------------ attention


def _mask(q_pos, k_pos, *, causal: bool, window: int, prefix: int):
    """q_pos: [Sq,1] int32, k_pos: [1,Sk] int32 -> bool [Sq,Sk] (True=keep)."""
    if not causal:
        return jnp.ones((q_pos.shape[0], k_pos.shape[1]), bool)
    ok = k_pos <= q_pos
    if window:
        ok &= k_pos > (q_pos - window)
    if prefix:
        ok |= k_pos < prefix
    return ok


def _group(q, kv_heads: int):
    """[B,S,H,D] -> [B,S,KH,G,D] grouped-query layout (no KV repeat)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, kv_heads, h // kv_heads, d)


NEG_INF = -1e30


def attention_naive(q, k, v, *, q_pos, k_pos, causal=True, window=0, prefix=0):
    """Materialized-scores attention. [B,Sq,H,D] x [B,Sk,KH,D] -> [B,Sq,H,D]."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    qg = _group(q, kh) * (d ** -0.5)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    m = _mask(q_pos[:, None], k_pos[None, :], causal=causal, window=window, prefix=prefix)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(b, sq, h, d)


def attention_chunked(q, k, v, *, q_pos, k_pos, causal=True, window=0, prefix=0,
                      chunk=1024, unroll=False):
    """Online-softmax scan over KV chunks (memory O(Sq*chunk) instead of Sq*Sk)."""
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    if sk % chunk != 0 or sk <= chunk:
        return attention_naive(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                               window=window, prefix=prefix)
    n_chunks = sk // chunk
    qg = (_group(q, kh) * (d ** -0.5)).astype(q.dtype)
    ks = k.reshape(b, n_chunks, chunk, kh, d).swapaxes(0, 1)
    vs = v.reshape(b, n_chunks, chunk, kh, d).swapaxes(0, 1)
    kp = k_pos.reshape(n_chunks, chunk)

    g = h // kh
    acc0 = jnp.zeros((b, kh, g, sq, d), jnp.float32)
    m0 = jnp.full((b, kh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, kpc = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc, preferred_element_type=jnp.float32)
        msk = _mask(q_pos[:, None], kpc[None, :], causal=causal, window=window, prefix=prefix)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vc).astype(jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    if unroll:  # dry-run exact accounting: Python loop instead of lax.scan
        carry = (acc0, m0, l0)
        for i in range(n_chunks):
            carry, _ = body(carry, (ks[i], vs[i], kp[i]))
        acc, _, l = carry
    else:
        (acc, _, l), _ = jax.lax.scan(body, (acc0, m0, l0), (ks, vs, kp))
    o = acc / jnp.maximum(l[..., None], 1e-30)  # [b,kh,g,sq,d]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, kh * g, d).astype(q.dtype)


def attention_swa_block(q, k, v, *, q_pos, window: int, chunk: int):
    """Block-local sliding-window attention (§Perf): each query chunk attends
    only to its own and the previous key chunk — O(S*2c) score work instead
    of O(S*S_kv). Requires window <= chunk, self-attention, s % chunk == 0."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    nc = s // chunk
    qc = (_group(q, kh) * (d ** -0.5)).reshape(b, nc, chunk, kh, g, d)
    kc = k.reshape(b, nc, chunk, kh, d)
    vc = v.reshape(b, nc, chunk, kh, d)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kk = jnp.concatenate([kprev, kc], axis=2)  # [b,nc,2c,kh,d]
    vv = jnp.concatenate([vprev, vc], axis=2)
    s_ = jnp.einsum("bcqkgd,bcskd->bckgqs", qc, kk,
                    preferred_element_type=jnp.float32)
    qp = q_pos.reshape(nc, chunk)
    kp = jnp.concatenate([qp - chunk, qp], axis=1)  # [nc, 2c]
    msk = (kp[:, None, :] <= qp[:, :, None]) & \
          (kp[:, None, :] > qp[:, :, None] - window) & (kp[:, None, :] >= 0)
    s_ = jnp.where(msk[None, :, None, None], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1).astype(q.dtype)
    o = jnp.einsum("bckgqs,bcskd->bcqkgd", p, vv)
    return o.reshape(b, s, h, d)


def attention(q, k, v, *, q_pos, k_pos, causal=True, window=0, prefix=0,
              impl="auto", chunk=1024, unroll=False):
    s_q, s_kv = q.shape[1], k.shape[1]
    if impl == "swa_block" or (
        impl == "auto" and causal and window and not prefix
        and s_q == s_kv and window <= chunk and s_q % chunk == 0
        and s_q >= 2 * chunk
    ):
        return attention_swa_block(q, k, v, q_pos=q_pos, window=window,
                                   chunk=max(window, chunk if s_q % chunk == 0 else window))
    if impl == "naive" or (impl == "auto" and s_kv <= max(chunk, 4096)):
        return attention_naive(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                               window=window, prefix=prefix)
    return attention_chunked(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                             window=window, prefix=prefix, chunk=chunk,
                             unroll=unroll)


# ----------------------------------------------------- flash-decode (sharded)


def _row_update(cache, new_row, safe, in_range):
    """Row-granular cache write: read 1 row, select, write 1 row — instead of
    a full-cache where() copy (§Perf: cuts decode cache traffic ~3x)."""
    b, _, kh, d = new_row.shape
    old = jax.lax.dynamic_slice(cache, (0, safe, 0, 0), (cache.shape[0], 1, kh, d))
    row = jnp.where(in_range, new_row.astype(cache.dtype), old)
    return jax.lax.dynamic_update_slice(cache, row, (0, safe, 0, 0))


def _decode_core(q, k_cache, v_cache, new_k, new_v, pos, *, s_local, model_axis,
                 update=True, update_mode="full"):
    """Manual (shard_map) decode-attention body. Shapes are per-shard:

      q:        [B, 1, H, D]   (replicated over model axis)
      k_cache:  [B, S_l, KH, D] (sequence-sharded over model axis)
      new_k/v:  [B, 1, KH, D]
      pos:      [] int32 — current decode position (cache valid in [0, pos])
    Returns (out [B,1,H,D] replicated, updated k_cache, v_cache).
    """
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    idx = jax.lax.axis_index(model_axis)
    offset = idx * s_local
    if update:
        # ---- cache update (write lands on exactly one shard)
        local = pos - offset
        in_range = (local >= 0) & (local < s_local)
        safe = jnp.clip(local, 0, s_local - 1)
        if update_mode == "row":
            k_cache = _row_update(k_cache, new_k, safe, in_range)
            v_cache = _row_update(v_cache, new_v, safe, in_range)
        else:
            upd_k = jax.lax.dynamic_update_slice(k_cache, new_k.astype(k_cache.dtype), (0, safe, 0, 0))
            upd_v = jax.lax.dynamic_update_slice(v_cache, new_v.astype(v_cache.dtype), (0, safe, 0, 0))
            k_cache = jnp.where(in_range, upd_k, k_cache)
            v_cache = jnp.where(in_range, upd_v, v_cache)
    # ---- partial attention over the local KV slice
    qg = _group(q, kh)[:, 0] * (d ** -0.5)  # [B,KH,G,D]
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32)
    k_pos = offset + jnp.arange(s_local)
    valid = k_pos[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(axis=-1)  # [B,KH,G]
    m_g = jax.lax.pmax(m, model_axis)
    p = jnp.exp(s - m_g[..., None])
    num = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache).astype(jnp.float32)
    den = p.sum(axis=-1)
    num = jax.lax.psum(num, model_axis)
    den = jax.lax.psum(den, model_axis)
    o = (num / jnp.maximum(den[..., None], 1e-30)).astype(q.dtype)
    return o.reshape(b, 1, h, d), k_cache, v_cache


def flash_decode_attention(ctx: ShardingCtx, q, k_cache, v_cache, new_k, new_v, pos,
                           update=True, update_mode="full"):
    """Sequence-sharded decode attention (context parallelism over `model`).

    Falls back to a single-shard jnp path when no mesh is present.
    """
    s_total = k_cache.shape[1]
    if ctx.mesh is None or "model" not in ctx.axis_sizes or not ctx.use_shard_map:
        return _single_decode(q, k_cache, v_cache, new_k, new_v, pos, update)
    n_model = ctx.axis_sizes["model"]
    if s_total % n_model != 0:
        return _single_decode(q, k_cache, v_cache, new_k, new_v, pos, update)
    s_local = s_total // n_model

    mesh = ctx.mesh
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    q_s = P(bspec, None, None, None)
    cache_s = P(bspec, "model", None, None)
    new_s = P(bspec, None, None, None)

    fn = functools.partial(_decode_core, s_local=s_local, model_axis="model",
                           update=update, update_mode=update_mode)
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(q_s, cache_s, cache_s, new_s, new_s, P()),
        out_specs=(q_s, cache_s, cache_s),
        check_vma=False,
    )(q, k_cache, v_cache, new_k, new_v, pos)


def _single_decode(q, k_cache, v_cache, new_k, new_v, pos, update=True):
    """Unsharded decode attention (CPU smoke tests)."""
    if update:
        k_cache = jax.lax.dynamic_update_slice(k_cache, new_k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, new_v.astype(v_cache.dtype), (0, pos, 0, 0))
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    qg = _group(q, kh)[:, 0] * (d ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32)
    valid = jnp.arange(k_cache.shape[1])[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache)
    return o.reshape(b, 1, h, d), k_cache, v_cache


def decode_attention(ctx: ShardingCtx, q, k_cache, v_cache, new_k, new_v, pos,
                     update=True, update_mode="full"):
    """Public decode-attention entry: sharded flash-decode when a mesh exists."""
    if ctx.mesh is None:
        return _single_decode(q, k_cache, v_cache, new_k, new_v, pos, update)
    return flash_decode_attention(ctx, q, k_cache, v_cache, new_k, new_v, pos,
                                  update, update_mode)


# --------------------------------------------------------- paged KV (serving)


def paged_cache_append(k_pool, v_pool, block_tables, lengths, new_k, new_v):
    """Write one new KV row per request into its paged block.

    k_pool/v_pool: [NB, blk, KH, D] one layer's block pool; block_tables:
    [B, M] int32 block ids; lengths: [B] int32 tokens already stored — row b
    lands in block `block_tables[b, lengths[b] // blk]` at offset
    `lengths[b] % blk`. new_k/new_v: [B, 1, KH, D]. Requests own disjoint
    blocks (serve.kv_pager invariant) so the scatter indices never collide,
    except padding rows which all target the reserved garbage block 0.
    """
    b = lengths.shape[0]
    blk = k_pool.shape[1]
    bids = block_tables[jnp.arange(b), lengths // blk]
    offs = lengths % blk
    k_pool = k_pool.at[bids, offs].set(new_k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[bids, offs].set(new_v[:, 0].astype(v_pool.dtype))
    return k_pool, v_pool


def paged_cache_append_chunk(k_pool, v_pool, block_tables, start, new_k, new_v,
                             n_valid):
    """Write a chunk of consecutive KV rows into paged blocks.

    The chunked-prefill analogue of `paged_cache_append`: rows
    ``i < n_valid`` of the (right-padded) chunk land at logical positions
    ``start + i`` through the block table; padding rows are redirected to
    the reserved garbage block 0 so they never clobber real pages.

    k_pool/v_pool: [NB, blk, KH, D]; block_tables: [B, M] int32; start/
    n_valid: [] int32 (one request per call — chunks are per-request);
    new_k/new_v: [B, C, KH, D].
    """
    b, c = new_k.shape[0], new_k.shape[1]
    blk = k_pool.shape[1]
    m = block_tables.shape[1]
    idx = jnp.arange(c, dtype=jnp.int32)
    pos = start + idx                                   # [C] absolute positions
    bi = jnp.minimum(pos // blk, m - 1)
    bids = block_tables[:, bi]                          # [B, C]
    valid = (idx < n_valid) & (pos // blk < m)
    bids = jnp.where(valid[None, :], bids, 0)           # padding -> garbage
    offs = jnp.broadcast_to(pos % blk, (b, c))
    k_pool = k_pool.at[bids, offs].set(new_k.astype(k_pool.dtype))
    v_pool = v_pool.at[bids, offs].set(new_v.astype(v_pool.dtype))
    return k_pool, v_pool


def paged_prefill_attention(q, k_pool, v_pool, block_tables, q_pos):
    """Causal chunk attention against a paged KV cache (chunked prefill).

    q: [B, C, H, D] — a chunk of prompt queries at absolute positions
    `q_pos` [B, C]; k_pool/v_pool: [NB, blk, KH, D]; block_tables: [B, M]
    int32 (padded with the garbage block 0). Query i attends every pool
    position <= q_pos[b, i] — its own chunk's rows were appended first
    (`paged_cache_append_chunk`), earlier rows hold the already-prefilled
    (or prefix-cache-shared) prefix. Returns [B, C, H, D]; padded query
    rows produce garbage the caller discards.
    """
    b, c, h, d = q.shape
    blk, kh = k_pool.shape[1], k_pool.shape[2]
    m = block_tables.shape[1]
    k = k_pool[block_tables].reshape(b, m * blk, kh, d)
    v = v_pool[block_tables].reshape(b, m * blk, kh, d)
    qg = _group(q, kh) * (d ** -0.5)                   # [B,C,KH,G,D]
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    valid = jnp.arange(m * blk)[None, None, :] <= q_pos[:, :, None]  # [B,C,S]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(b, c, h, d)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths):
    """Decode attention over a paged KV cache (jnp twin of the Pallas
    `kernels/decode_attention.paged_flash_decode`).

    q: [B, 1, H, D]; k_pool/v_pool: [NB, blk, KH, D]; block_tables: [B, M]
    int32 (padded with the garbage block 0); lengths: [B] int32 — request b
    attends key positions < lengths[b]. Returns [B, 1, H, D]. Rows with
    lengths == 0 (padding slots in a round) produce garbage the caller
    discards.
    """
    b, _, h, d = q.shape
    blk, kh = k_pool.shape[1], k_pool.shape[2]
    m = block_tables.shape[1]
    k = k_pool[block_tables].reshape(b, m * blk, kh, d)
    v = v_pool[block_tables].reshape(b, m * blk, kh, d)
    qg = _group(q, kh)[:, 0] * (d ** -0.5)  # [B,KH,G,D]
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k, preferred_element_type=jnp.float32)
    valid = jnp.arange(m * blk)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v)
    return o.reshape(b, 1, h, d)

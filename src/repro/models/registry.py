"""Model facade: bind an ArchConfig (+ShardingCtx) to the unified LM functions."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSuite
from repro.models import lm
from repro.models import params as pm
from repro.sharding import NULL_CTX, ShardingCtx


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    ctx: ShardingCtx = NULL_CTX

    # ------------------------------------------------------------- params
    def param_specs(self):
        return lm.param_specs(self.cfg)

    def abstract_params(self):
        return pm.abstract(self.param_specs())

    def init(self, rng):
        return pm.initialize(rng, self.param_specs())

    def param_shardings(self):
        return pm.shardings(self.param_specs(), self.ctx)

    def param_partition_specs(self):
        return pm.partition_specs(self.param_specs(), self.ctx)

    def n_params(self) -> int:
        return pm.count(self.param_specs())

    # -------------------------------------------------------------- steps
    def loss(self, params, batch):
        return lm.loss_fn(params, batch, self.cfg, self.ctx)

    def prefill(self, params, batch, pad_to=None):
        return lm.prefill(params, batch, self.cfg, self.ctx, pad_to=pad_to)

    def decode_step(self, params, cache, batch):
        return lm.decode_step(params, cache, batch, self.cfg, self.ctx)

    def decode_step_paged(self, params, k_pools, v_pools, block_tables, lengths, batch):
        return lm.decode_step_paged(params, k_pools, v_pools, block_tables,
                                    lengths, batch, self.cfg, self.ctx)

    def prefill_chunk_paged(self, params, k_pools, v_pools, block_tables,
                            start, batch, n_valid):
        return lm.prefill_chunk_paged(params, k_pools, v_pools, block_tables,
                                      start, batch, n_valid, self.cfg, self.ctx)

    def supports_paged_decode(self) -> bool:
        return lm.supports_paged_decode(self.cfg)

    # -------------------------------------------------------------- cache
    def cache_specs(self, shape: ShapeSuite):
        return lm.cache_specs(self.cfg, shape)

    def abstract_cache(self, shape: ShapeSuite):
        return pm.abstract(self.cache_specs(shape))

    def cache_shardings(self, shape: ShapeSuite):
        return pm.shardings(self.cache_specs(shape), self.ctx)


def build_model(cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX) -> Model:
    return Model(cfg=cfg, ctx=ctx)

"""Mixture-of-Experts layer: top-k router + two dispatch backends.

Dispatch is the paper's hash-join probe (CoroAMU Table II "HJ"): every token is
a tuple probing the expert "hash table". Two backends:

  * dense — mask-based einsum over all experts. Exact (dropless); used for
    reduced smoke configs and as the oracle.
  * ep    — expert-parallel: sort tokens by expert, capacity-bounded dispatch
    buffers, all_to_all over the `model` axis, local grouped matmul,
    all_to_all back, weighted combine. This is the collective-heavy path the
    roofline/§Perf work targets, and on TPU kernels/moe_gmm streams expert
    weights with decoupled DMA.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec
from repro.sharding import ShardingCtx, shard_map


def moe_param_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    e, dm, dff = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    return {
        "router": ParamSpec((dm, e), ("embed", "experts"), init="fan_in"),
        "w_gate": ParamSpec((e, dm, dff), ("experts", "embed", "mlp"), init="fan_in"),
        "w_up": ParamSpec((e, dm, dff), ("experts", "embed", "mlp"), init="fan_in"),
        "w_down": ParamSpec((e, dff, dm), ("experts", "mlp", "embed"), init="fan_in"),
    }


def router_topk(x, w_router, top_k: int):
    """x:[T,d] -> (gates [T,k], experts [T,k] int32, aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style)
    e = w_router.shape[1]
    me = probs.mean(0)
    one_hot = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
    ce = one_hot.mean(0)
    aux = e * jnp.sum(me * ce)
    return gates.astype(x.dtype), experts.astype(jnp.int32), aux


GMM_F_TILE = 128


def _gmm_eligible(xs, wg, wu, wd) -> bool:
    """The streamed-weight kernel wants matched [E, ...] batching and tile-
    divisible output widths (dff and d_model for the down projection)."""
    return (xs.ndim == 3 and xs.shape[0] == wg.shape[0]
            and wg.shape[-1] % GMM_F_TILE == 0
            and wd.shape[-1] % GMM_F_TILE == 0)


def _expert_ffn(xs, wg, wu, wd, *, use_gmm: bool | None = None):
    """Per-expert SwiGLU. On TPU (when shapes allow) each grouped matmul is
    the `kernels/moe_gmm` coroutine pipeline — expert weights are the far-
    memory objects, streamed HBM->VMEM tile-by-tile while the MXU consumes
    the previous tile. The dense einsum below is the jnp twin, kept as the
    interpret-mode / CPU fallback (ROADMAP: MoE expert-parallel dispatch)."""
    if use_gmm is None:
        from repro.core.machine import default_interpret
        use_gmm = not default_interpret()
    if use_gmm and _gmm_eligible(xs, wg, wu, wd):
        from repro.kernels.moe_gmm.ops import moe_gmm
        h = jax.nn.silu(moe_gmm(xs, wg.astype(xs.dtype), f_tile=GMM_F_TILE))
        h = h * moe_gmm(xs, wu.astype(xs.dtype), f_tile=GMM_F_TILE)
        return moe_gmm(h, wd.astype(xs.dtype), f_tile=GMM_F_TILE)
    h = jax.nn.silu(jnp.einsum("...td,...df->...tf", xs, wg.astype(xs.dtype)))
    h = h * jnp.einsum("...td,...df->...tf", xs, wu.astype(xs.dtype))
    return jnp.einsum("...tf,...fd->...td", h, wd.astype(xs.dtype))


def moe_dense(p, x, cfg: ArchConfig):
    """Oracle/dense backend: computes every expert for every token via masks.

    x: [B,S,d]. Exact dropless combine; O(T * E * d * dff) flops.
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gates, experts, aux = router_topk(xt, p["router"], cfg.top_k)
    outs = _expert_ffn(xt[None], p["w_gate"], p["w_up"], p["w_down"])  # [E,T,d]
    comb = jax.nn.one_hot(experts, cfg.n_experts, dtype=xt.dtype) * gates[..., None]
    y = jnp.einsum("tke,etd->td", comb, outs)
    return y.reshape(b, s, d), aux


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, (cap + 7) // 8 * 8)


def _dispatch_local(xt, gates, experts, cfg: ArchConfig, capacity: int):
    """Sort-based capacity dispatch. xt:[T,d] -> buf [E,C,d] + combine meta."""
    t, d = xt.shape
    k = cfg.top_k
    e = cfg.n_experts
    flat_e = experts.reshape(-1)                     # [T*k]
    flat_t = jnp.repeat(jnp.arange(t), k)            # token id per assignment
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)                      # group by expert
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within expert group
    ones = jnp.ones_like(se)
    pos_in_e = jax.lax.associative_scan(jnp.add, ones) - 1
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos_in_e = pos_in_e - seg_start[se]
    keep = pos_in_e < capacity
    slot = se * capacity + jnp.where(keep, pos_in_e, 0)
    buf = jnp.zeros((e * capacity, d), xt.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[st], 0))
    meta = dict(slot=slot, token=st, gate=sg, keep=keep)
    return buf.reshape(e, capacity, d), meta


def _combine_local(buf, meta, t: int):
    """buf [E,C,d] -> y [T,d] weighted by gates."""
    e, c, d = buf.shape
    flat = buf.reshape(e * c, d)
    contrib = flat[meta["slot"]] * meta["gate"][:, None] * meta["keep"][:, None]
    y = jnp.zeros((t, d), buf.dtype).at[meta["token"]].add(contrib)
    return y


def _mesh_bspec(ctx: ShardingCtx):
    dp = tuple(a for a in ctx.mesh.axis_names if a in ("pod", "data"))
    return dp if len(dp) > 1 else dp[0]


def _expert_specs():
    return {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }


def moe_ep_a2a(p, x, cfg: ArchConfig, ctx: ShardingCtx):
    """SP+EP backend: tokens sequence-sharded over `model`, experts sharded
    over `model`; two all_to_all exchanges move tokens to/from expert owners.

    Per-shard: tokens [T_l, d] -> dispatch [E,C,d] -> all_to_all over model
    -> local experts [E_l, n_model*C, d] -> ffn -> all_to_all back -> combine.
    """
    mesh = ctx.mesh
    n_model = ctx.axis_sizes["model"]
    bspec = _mesh_bspec(ctx)
    b, s, d = x.shape
    all_axes = tuple(mesh.axis_names)

    def fn(p_l, x_l):
        bl, sl, _ = x_l.shape
        xt = x_l.reshape(bl * sl, d)
        gates, experts, aux = router_topk(xt, p_l["router"], cfg.top_k)
        cap = _capacity(xt.shape[0], cfg)
        buf, meta = _dispatch_local(xt, gates, experts, cfg, cap)   # [E,C,d]
        e, c, _ = buf.shape
        e_l = e // n_model
        buf = buf.reshape(n_model, e_l, c, d)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
        # recv[src, e_l, c, d]: capacity blocks from every source shard
        recv = recv.swapaxes(0, 1).reshape(e_l, n_model * c, d)
        out = _expert_ffn(recv, p_l["w_gate"], p_l["w_up"], p_l["w_down"])
        out = out.reshape(e_l, n_model, c, d).swapaxes(0, 1)
        back = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0)
        y = _combine_local(back.reshape(e, c, d), meta, xt.shape[0])
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(bl, sl, d), aux

    in_x = P(bspec, "model", None)  # sequence-parallel tokens
    y, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(_expert_specs(), in_x),
        out_specs=(in_x, P()),
        check_vma=False,
    )(p, x)
    return y, aux


def moe_ep_replicated(p, x, cfg: ArchConfig, ctx: ShardingCtx):
    """EP for tokens replicated over `model` (decode: seq=1). Each shard
    computes its local experts for all its tokens; psum combines over model."""
    mesh = ctx.mesh
    n_model = ctx.axis_sizes["model"]
    bspec = _mesh_bspec(ctx)
    b, s, d = x.shape
    e_l = cfg.n_experts // n_model
    all_axes = tuple(mesh.axis_names)

    def fn(p_l, x_l):
        bl, sl, _ = x_l.shape
        xt = x_l.reshape(bl * sl, d)
        gates, experts, aux = router_topk(xt, p_l["router"], cfg.top_k)
        e0 = jax.lax.axis_index("model") * e_l
        rel = experts - e0
        local = (rel >= 0) & (rel < e_l)
        outs = _expert_ffn(xt[None], p_l["w_gate"], p_l["w_up"], p_l["w_down"])
        comb = jax.nn.one_hot(jnp.where(local, rel, 0), e_l, dtype=xt.dtype)
        comb = comb * (gates * local.astype(gates.dtype))[..., None]
        y = jnp.einsum("tke,etd->td", comb, outs)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(bl, sl, d), aux

    in_x = P(bspec, None, None)
    y, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(_expert_specs(), in_x),
        out_specs=(in_x, P()),
        check_vma=False,
    )(p, x)
    return y, aux


def moe_layer(p, x, cfg: ArchConfig, ctx: ShardingCtx):
    impl = cfg.moe_impl
    n_model = ctx.axis_sizes.get("model", 0) if ctx.mesh is not None else 0
    ep_ok = (
        ctx.mesh is not None and ctx.use_shard_map and n_model
        and cfg.n_experts % n_model == 0
    )
    if impl == "auto":
        impl = "ep" if ep_ok else "dense"
    if impl == "ep" and ep_ok:
        if x.shape[1] % n_model == 0:
            return moe_ep_a2a(p, x, cfg, ctx)
        return moe_ep_replicated(p, x, cfg, ctx)
    return moe_dense(p, x, cfg)

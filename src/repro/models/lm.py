"""Unified LM: dense / MoE / SSM / hybrid / enc-dec / VLM from one block set.

Step kinds:
  loss(params, batch)                 - training forward (full seq, causal)
  prefill(params, batch) -> cache     - one-shot prefill building KV caches
  decode_step(params, cache, batch)   - one new token per sequence

Layer stacking is `lax.scan` for the real paths and a Python unroll for
dry-runs (`cfg.scan_layers=False`) so XLA cost analysis counts every layer
(see DESIGN.md §3.2).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSuite, cache_seq_len, token_split
from repro.models import params as pm
from repro.models.common import (
    NEG_INF,
    apply_rope,
    attention,
    decode_attention,
    embed_lookup,
    paged_cache_append,
    paged_cache_append_chunk,
    paged_decode_attention,
    paged_prefill_attention,
    rms_norm,
    sinusoid_pos_emb,
    swiglu,
)
from repro.models.moe import moe_layer, moe_param_specs
from repro.models.ssm import (
    ssm_cache_shape,
    ssm_decode,
    ssm_forward,
    ssm_param_specs,
)
from repro.models.params import ParamSpec
from repro.sharding import NULL_CTX, ShardingCtx

# ------------------------------------------------------------- param specs


def _attn_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    s: Dict[str, ParamSpec] = {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "wk": ParamSpec((d, kh, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wv": ParamSpec((d, kh, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"), init="fan_in"),
    }
    if cfg.use_bias:
        s["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((kh, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((kh, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    return s


def _mlp_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "w_gate": ParamSpec((d, f), ("embed", "mlp"), init="fan_in"),
        "w_up": ParamSpec((d, f), ("embed", "mlp"), init="fan_in"),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), init="fan_in"),
    }


def _layer_specs(cfg: ArchConfig, *, cross: bool = False, encoder: bool = False) -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    if cfg.family == "ssm":
        s["ssm_ln"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
        s["ssm"] = ssm_param_specs(cfg)
        return s
    s["attn"] = _attn_specs(cfg)
    if cfg.hybrid:
        s["ssm"] = ssm_param_specs(cfg)
    if cross:
        s["cross"] = _attn_specs(cfg)
    if cfg.moe:
        s["moe"] = moe_param_specs(cfg)
        s["moe_ln"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
    else:
        s["mlp"] = _mlp_specs(cfg)
    return s


def _stack(tree, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype, s.init, s.scale),
        tree,
        is_leaf=pm.is_spec,
    )


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed")),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "layers": _stack(_layer_specs(cfg, cross=cfg.enc_dec), cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, cfg.vocab), ("embed", "vocab"))
    if cfg.enc_dec:
        specs["enc_layers"] = _stack(
            _layer_specs(cfg, encoder=True), cfg.n_enc_layers
        )
        specs["enc_norm"] = ParamSpec((d,), ("embed",), init="ones")
        max_dec = 32768 // cfg.dec_ratio
        specs["dec_pos_embed"] = ParamSpec((max_dec, d), ("seq", "embed"))
    if cfg.vlm:
        specs["patch_proj"] = ParamSpec((d, d), ("embed", None), init="fan_in")
    if cfg.param_dtype != "float32":
        dt = jnp.dtype(cfg.param_dtype)
        specs = jax.tree.map(
            lambda s: ParamSpec(s.shape, s.axes, dt, s.init, s.scale),
            specs, is_leaf=pm.is_spec,
        )
    return specs


# ---------------------------------------------------------------- attention


def _project_qkv(p, h, cfg: ArchConfig):
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _attn_out(p, o, cfg: ArchConfig):
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    if "bo" in p:
        out = out + p["bo"].astype(o.dtype)
    return out


def attn_full(p, x, cfg: ArchConfig, ctx: ShardingCtx, *, positions, causal=True,
              prefix=0, rope=True, kv_out=False):
    """Full-sequence attention block (train / prefill / encoder)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(
        q, k, v,
        q_pos=positions, k_pos=positions,
        causal=causal, window=cfg.sliding_window, prefix=prefix,
        impl=cfg.attn_impl, chunk=cfg.attn_chunk, unroll=not cfg.scan_layers,
    )
    out = _attn_out(p, o, cfg)
    if kv_out:
        return out, (k, v)
    return out


def attn_cross_full(p, x, enc_out, cfg: ArchConfig, *, kv_out=False):
    """Cross-attention over encoder output (no mask, no rope)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, _, _ = _project_qkv(p, h, cfg)
    dt = h.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    sq, sk = x.shape[1], enc_out.shape[1]
    o = attention(q, k, v, q_pos=jnp.arange(sq), k_pos=jnp.arange(sk),
                  causal=False, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                  unroll=not cfg.scan_layers)
    out = _attn_out(p, o, cfg)
    if kv_out:
        return out, (k, v)
    return out


def ring_decode_attention(q, k_cache, v_cache, new_k, new_v, pos, window: int):
    """Sliding-window ring-buffer decode (cache slot = position % window)."""
    slot = pos % window
    k_cache = jax.lax.dynamic_update_slice(k_cache, new_k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, new_v.astype(v_cache.dtype), (0, slot, 0, 0))
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    qg = q.reshape(b, kh, h // kh, d) * (d ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32)
    s_idx = jnp.arange(window)
    k_pos = pos - (pos - s_idx) % window
    valid = k_pos >= 0
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache)
    return o.reshape(b, 1, h, d), k_cache, v_cache


def attn_decode(p, x, cache, cfg: ArchConfig, ctx: ShardingCtx, *, pos, cross=False,
                rope=True):
    """One-token attention against a KV cache (self or cross)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg)
    if rope and not cross:
        posv = jnp.full((1,), 0, jnp.int32) + pos
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    kc, vc = cache
    if cross:
        # static cache: attend over all encoder positions, no write-back
        o, _, _ = decode_attention(ctx, q, kc, vc, k, v,
                                   jnp.asarray(kc.shape[1] - 1, jnp.int32),
                                   update=False)
        new_cache = (kc, vc)
    elif cfg.sliding_window and kc.shape[1] <= cfg.sliding_window:
        o, kc, vc = ring_decode_attention(q, kc, vc, k, v, pos, kc.shape[1])
        new_cache = (kc, vc)
    else:
        o, kc, vc = decode_attention(ctx, q, kc, vc, k, v, pos,
                                     update_mode=cfg.cache_update)
        new_cache = (kc, vc)
    return _attn_out(p, o, cfg), new_cache


# ------------------------------------------------------------------- blocks


def block_full(p, x, cfg: ArchConfig, ctx: ShardingCtx, *, positions, causal=True,
               prefix=0, rope=True, cross_src=None, build_cache=False):
    """One layer, full-sequence. Returns (x, aux, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    cache_entry: Dict[str, Any] = {}
    if cfg.family == "ssm":
        h = rms_norm(x, p["ssm_ln"], cfg.norm_eps)
        if build_cache:
            out, h_fin, conv = ssm_forward(p["ssm"], h, cfg, return_state=True)
            cache_entry["h"], cache_entry["conv"] = h_fin, conv
        else:
            out = ssm_forward(p["ssm"], h, cfg)
        return x + out, aux, cache_entry

    if cfg.hybrid:
        attn_o, kv = attn_full(p["attn"], x, cfg, ctx, positions=positions,
                               causal=causal, prefix=prefix, rope=rope, kv_out=True)
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        if build_cache:
            ssm_o, h_fin, conv = ssm_forward(p["ssm"], h, cfg, return_state=True)
            cache_entry["h"], cache_entry["conv"] = h_fin, conv
        else:
            ssm_o = ssm_forward(p["ssm"], h, cfg)
        x = x + 0.5 * (attn_o + ssm_o)
    else:
        attn_o, kv = attn_full(p["attn"], x, cfg, ctx, positions=positions,
                               causal=causal, prefix=prefix, rope=rope, kv_out=True)
        x = x + attn_o
    if build_cache and cfg.has_attention:
        k, v = kv
        if cfg.sliding_window:
            w = cfg.sliding_window
            s_full = k.shape[1]
            if s_full >= w:
                # ring layout: slot = position % w; the last w positions are a
                # cyclic rotation of the slots by (s_full % w)
                k, v = k[:, -w:], v[:, -w:]
                shift = s_full % w
                if shift:
                    k = jnp.roll(k, shift, axis=1)
                    v = jnp.roll(v, shift, axis=1)
        cache_entry["k"], cache_entry["v"] = k, v

    if cross_src is not None:
        if build_cache:
            cross_o, ckv = attn_cross_full(p["cross"], x, cross_src, cfg, kv_out=True)
            cache_entry["cross_k"], cache_entry["cross_v"] = ckv
        else:
            cross_o = attn_cross_full(p["cross"], x, cross_src, cfg)
        x = x + cross_o

    if cfg.moe:
        h = rms_norm(x, p["moe_ln"], cfg.norm_eps)
        moe_o, aux = moe_layer(p["moe"], h, cfg, ctx)
        x = x + moe_o
    elif "mlp" in p:
        m = p["mlp"]
        h = rms_norm(x, m["ln"], cfg.norm_eps)
        x = x + swiglu(h, m["w_gate"].astype(h.dtype), m["w_up"].astype(h.dtype),
                       m["w_down"].astype(h.dtype))
    return x, aux, cache_entry


def block_decode(p, x, layer_cache, cfg: ArchConfig, ctx: ShardingCtx, *, pos):
    """One layer, one token. Returns (x, new_layer_cache)."""
    new_cache: Dict[str, Any] = {}
    if cfg.family == "ssm":
        h = rms_norm(x, p["ssm_ln"], cfg.norm_eps)
        out, st = ssm_decode(p["ssm"], {"h": layer_cache["h"], "conv": layer_cache["conv"]}, h, cfg)
        new_cache.update(st)
        return x + out, new_cache

    rope = not cfg.enc_dec
    if cfg.hybrid:
        attn_o, kv = attn_decode(p["attn"], x, (layer_cache["k"], layer_cache["v"]),
                                 cfg, ctx, pos=pos, rope=rope)
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        ssm_o, st = ssm_decode(p["ssm"], {"h": layer_cache["h"], "conv": layer_cache["conv"]}, h, cfg)
        new_cache.update(st)
        new_cache["k"], new_cache["v"] = kv
        x = x + 0.5 * (attn_o + ssm_o)
    else:
        attn_o, kv = attn_decode(p["attn"], x, (layer_cache["k"], layer_cache["v"]),
                                 cfg, ctx, pos=pos, rope=rope)
        new_cache["k"], new_cache["v"] = kv
        x = x + attn_o

    if "cross" in p:
        cross_o, _ = attn_decode(p["cross"], x,
                                 (layer_cache["cross_k"], layer_cache["cross_v"]),
                                 cfg, ctx, pos=pos, cross=True)
        new_cache["cross_k"] = layer_cache["cross_k"]
        new_cache["cross_v"] = layer_cache["cross_v"]
        x = x + cross_o

    if cfg.moe:
        h = rms_norm(x, p["moe_ln"], cfg.norm_eps)
        moe_o, _ = moe_layer(p["moe"], h, cfg, ctx)
        x = x + moe_o
    elif "mlp" in p:
        m = p["mlp"]
        h = rms_norm(x, m["ln"], cfg.norm_eps)
        x = x + swiglu(h, m["w_gate"].astype(h.dtype), m["w_up"].astype(h.dtype),
                       m["w_down"].astype(h.dtype))
    return x, new_cache


# ------------------------------------------------------------ paged decode
#
# The serving engine (repro.serve) replaces the dense [B, max_len] KV caches
# with per-layer block pools: requests own disjoint fixed-size pages, a block
# table maps each request's logical positions onto pool blocks, and every
# request in a round decodes at its OWN position (ragged lengths — the dense
# path's single scalar `pos` becomes a [B] vector). Supported families:
# attention (+ MoE FFN); SSM/hybrid recurrent state, cross-attention, and
# ring (sliding-window) caches keep the dense path.


def paged_attn_decode(p, x, k_pool, v_pool, cfg: ArchConfig, *, block_tables,
                      lengths):
    """One-token attention against one layer's paged KV pool.

    x: [B, 1, d]; k_pool/v_pool: [NB, blk, KH, D]; lengths: [B] int32 tokens
    already stored per request (the new KV row is written at that position).
    Returns (attn_out, k_pool, v_pool).
    """
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg)
    posv = lengths[:, None]  # [B, 1] per-request decode position
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    k_pool, v_pool = paged_cache_append(k_pool, v_pool, block_tables, lengths, k, v)
    o = paged_decode_attention(q, k_pool, v_pool, block_tables, lengths + 1)
    return _attn_out(p, o, cfg), k_pool, v_pool


def paged_block_decode(p, x, k_pool, v_pool, cfg: ArchConfig, ctx: ShardingCtx,
                       *, block_tables, lengths):
    """One layer, one token per request, paged KV. Returns (x, k_pool, v_pool)."""
    attn_o, k_pool, v_pool = paged_attn_decode(
        p["attn"], x, k_pool, v_pool, cfg,
        block_tables=block_tables, lengths=lengths)
    x = _paged_ffn(p, x + attn_o, cfg, ctx)
    return x, k_pool, v_pool


def run_layers_decode_paged(layers, k_pools, v_pools, x, cfg: ArchConfig,
                            ctx: ShardingCtx, *, block_tables, lengths):
    """All layers over per-layer pools [L, NB, blk, KH, D]. Returns
    (x, k_pools, v_pools)."""

    def block_fn(lp, x, kp, vp):
        return paged_block_decode(lp, x, kp, vp, cfg, ctx,
                                  block_tables=block_tables, lengths=lengths)

    return _run_layers_paged(layers, k_pools, v_pools, x, cfg, block_fn)


def paged_attn_prefill_chunk(p, x, k_pool, v_pool, cfg: ArchConfig, *,
                             block_tables, start, n_valid):
    """Chunk attention against one layer's paged KV pool (chunked prefill).

    x: [1, C, d] — a chunk of prompt hidden states at absolute positions
    ``start + i``; the chunk's KV rows are appended into the pool first
    (padding rows masked to the garbage page), then every query attends
    causally over all pool positions <= its own. Returns
    (attn_out, k_pool, v_pool).
    """
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg)
    c = x.shape[1]
    pos = (start + jnp.arange(c, dtype=jnp.int32))[None]  # [1, C]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    k_pool, v_pool = paged_cache_append_chunk(k_pool, v_pool, block_tables,
                                              start, k, v, n_valid)
    o = paged_prefill_attention(q, k_pool, v_pool, block_tables, pos)
    return _attn_out(p, o, cfg), k_pool, v_pool


def _paged_ffn(p, x, cfg: ArchConfig, ctx: ShardingCtx):
    """The post-attention half every paged block shares (MoE or SwiGLU)."""
    if cfg.moe:
        h = rms_norm(x, p["moe_ln"], cfg.norm_eps)
        moe_o, _ = moe_layer(p["moe"], h, cfg, ctx)
        return x + moe_o
    if "mlp" in p:
        m = p["mlp"]
        h = rms_norm(x, m["ln"], cfg.norm_eps)
        x = x + swiglu(h, m["w_gate"].astype(h.dtype), m["w_up"].astype(h.dtype),
                       m["w_down"].astype(h.dtype))
    return x


def paged_block_prefill_chunk(p, x, k_pool, v_pool, cfg: ArchConfig,
                              ctx: ShardingCtx, *, block_tables, start, n_valid):
    """One layer, one prefill chunk, paged KV. Returns (x, k_pool, v_pool)."""
    attn_o, k_pool, v_pool = paged_attn_prefill_chunk(
        p["attn"], x, k_pool, v_pool, cfg,
        block_tables=block_tables, start=start, n_valid=n_valid)
    x = _paged_ffn(p, x + attn_o, cfg, ctx)
    return x, k_pool, v_pool


def _run_layers_paged(layers, k_pools, v_pools, x, cfg: ArchConfig, block_fn):
    """Scan (or unroll) `block_fn` over layers + per-layer pools."""

    def body(x, inp):
        lp, kp, vp = inp
        y, kp, vp = block_fn(lp, x, kp, vp)
        return y, (kp, vp)

    if cfg.scan_layers:
        x, (k_pools, v_pools) = jax.lax.scan(body, x, (layers, k_pools, v_pools))
        return x, k_pools, v_pools

    n = jax.tree.leaves(layers)[0].shape[0]
    kps, vps = [], []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], layers)
        x, (kp, vp) = body(x, (lp, k_pools[i], v_pools[i]))
        kps.append(kp)
        vps.append(vp)
    return x, jnp.stack(kps), jnp.stack(vps)


def prefill_chunk_paged(params, k_pools, v_pools, block_tables, start, batch,
                        n_valid, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX):
    """One chunk of a request's prefill through the paged pipeline.

    batch["tokens"]: [1, C] right-padded chunk; start: [] int32 absolute
    position of its first token; n_valid: [] int32 real tokens (the rest is
    padding whose KV writes are masked to the garbage page). The request's
    block table must already map every position < start + n_valid — shared
    prefix pages for positions < start (prefix-cache hit), private pages
    for the chunk itself (copy-on-write forked by the pager if the first
    page is shared). Returns (last_logits [1, V] at the chunk's final real
    token, k_pools, v_pools).
    """
    if not supports_paged_decode(cfg):
        raise ValueError(f"paged prefill unsupported for family {cfg.family!r} "
                         f"(sliding_window={cfg.sliding_window})")
    dt = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], batch["tokens"]).astype(dt)

    def block_fn(lp, x, kp, vp):
        return paged_block_prefill_chunk(lp, x, kp, vp, cfg, ctx,
                                         block_tables=block_tables,
                                         start=start, n_valid=n_valid)

    x, k_pools, v_pools = _run_layers_paged(params["layers"], k_pools, v_pools,
                                            x, cfg, block_fn)
    last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)  # [1,1,d]
    last = rms_norm(last, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, last, cfg, ctx)[:, 0]
    return logits, k_pools, v_pools


def supports_paged_decode(cfg: ArchConfig) -> bool:
    """Families the paged serving engine can drive (attention KV only)."""
    return (cfg.has_attention and not cfg.hybrid and not cfg.enc_dec
            and not cfg.vlm and not cfg.sliding_window)


def decode_step_paged(params, k_pools, v_pools, block_tables, lengths, batch,
                      cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX):
    """One decode step for a round of ragged requests over paged KV pools.

    batch["tokens"]: [B, 1]; lengths: [B] int32 — each request's stored token
    count (its new KV row is written there, then it attends to lengths+1
    positions). Returns (logits [B, 1, V], k_pools, v_pools).
    """
    if not supports_paged_decode(cfg):
        raise ValueError(f"paged decode unsupported for family {cfg.family!r} "
                         f"(sliding_window={cfg.sliding_window})")
    dt = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], batch["tokens"]).astype(dt)
    x, k_pools, v_pools = run_layers_decode_paged(
        params["layers"], k_pools, v_pools, x, cfg, ctx,
        block_tables=block_tables, lengths=lengths)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x, cfg, ctx)
    return logits, k_pools, v_pools


# --------------------------------------------------------------- layer stack


def run_layers_full(layers, x, cfg: ArchConfig, ctx: ShardingCtx, *, positions,
                    causal=True, prefix=0, rope=True, cross_src=None,
                    build_cache=False):
    """Apply all layers (scan or unrolled). Returns (x, aux_sum, stacked_cache)."""

    def body_fn(x, lp):
        y, aux, cache = block_full(lp, x, cfg, ctx, positions=positions,
                                   causal=causal, prefix=prefix, rope=rope,
                                   cross_src=cross_src, build_cache=build_cache)
        return y, aux, cache

    if cfg.remat:
        body_fn = jax.checkpoint(
            body_fn,
            policy=(jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                    if cfg.remat_policy == "dots" else None),
        )

    if cfg.scan_layers:
        def scan_body(carry, lp):
            x, aux = carry
            y, a, cache = body_fn(x, lp)
            return (y, aux + a), cache
        (x, aux), caches = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), layers)
        return x, aux, caches

    n = jax.tree.leaves(layers)[0].shape[0]
    aux = jnp.zeros((), jnp.float32)
    cache_list = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], layers)
        x, a, cache = body_fn(x, lp)
        aux = aux + a
        cache_list.append(cache)
    caches = (
        jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list) if cache_list and cache_list[0] else {}
    )
    return x, aux, caches


def run_layers_decode(layers, caches, x, cfg: ArchConfig, ctx: ShardingCtx, *, pos):
    def body(x, inp):
        lp, lc = inp
        y, nc = block_decode(lp, x, lc, cfg, ctx, pos=pos)
        return y, nc

    if cfg.scan_layers:
        def scan_body(x, inp):
            return body(x, inp)
        x, new_caches = jax.lax.scan(scan_body, x, (layers, caches))
        return x, new_caches

    n = jax.tree.leaves(layers)[0].shape[0]
    outs = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], layers)
        lc = jax.tree.map(lambda a: a[i], caches)
        x, nc = body(x, (lp, lc))
        outs.append(nc)
    new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, new_caches


# ------------------------------------------------------------------ frontend


def _embed_in(params, batch, cfg: ArchConfig, ctx: ShardingCtx):
    """Token (+stub-frontend) embedding. Returns (x, positions, text_offset)."""
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens).astype(dt)
    if cfg.vlm:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)  # gemma-style scaling
        patches = batch["patches"].astype(dt) @ params["patch_proj"].astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)
    x = ctx.constrain(x, ("batch", "seq", None))
    return x, positions


def _unembed(params, x, cfg: ArchConfig, ctx: ShardingCtx):
    dt = x.dtype
    table = params.get("lm_head")
    if table is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, table.astype(dt))
    return ctx.constrain(logits.astype(jnp.float32), ("batch", "seq", "vocab"))


def _xent(logits, targets):
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return (lse - gold).mean()


# ------------------------------------------------------------------- top API


def loss_fn(params, batch, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX):
    """Next-token CE loss (+ MoE aux). Returns (loss, metrics)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.enc_dec:
        frames = batch["frames"].astype(dt)
        enc_x = frames + sinusoid_pos_emb(frames.shape[1], cfg.d_model, dt)[None]
        enc_x = ctx.constrain(enc_x, ("batch", "seq", None))
        enc_pos = jnp.arange(frames.shape[1])
        enc_out, _, _ = run_layers_full(params["enc_layers"], enc_x, cfg, ctx,
                                        positions=enc_pos, causal=False, rope=False)
        enc_out = rms_norm(enc_out, params["enc_norm"], cfg.norm_eps)
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens).astype(dt)
        x = x + params["dec_pos_embed"][: x.shape[1]].astype(dt)[None]
        positions = jnp.arange(x.shape[1])
        x, aux, _ = run_layers_full(params["layers"], x, cfg, ctx,
                                    positions=positions, causal=True, rope=False,
                                    cross_src=enc_out)
    else:
        x, positions = _embed_in(params, batch, cfg, ctx)
        prefix = x.shape[1] - batch["tokens"].shape[1] if cfg.vlm else 0
        x, aux, _ = run_layers_full(params["layers"], x, cfg, ctx,
                                    positions=positions, causal=True,
                                    prefix=prefix, rope=not cfg.enc_dec)
        if cfg.vlm:
            x = x[:, prefix:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x, cfg, ctx)
    loss = _xent(logits, batch["targets"])
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux": aux}


def _pad_cache_seq(layer_caches, cfg: ArchConfig, pad_to: Optional[int]):
    """Pad attention caches along seq so decode can write past the prompt."""
    if not layer_caches:
        return layer_caches
    out = dict(layer_caches)
    for key in ("k", "v"):
        if key in out:
            kv = out[key]  # [L, B, S, KH, D]
            target = pad_to
            if cfg.sliding_window:
                target = min(cfg.sliding_window, pad_to) if pad_to else cfg.sliding_window
            if target and kv.shape[2] < target:
                pad = [(0, 0)] * kv.ndim
                pad[2] = (0, target - kv.shape[2])
                out[key] = jnp.pad(kv, pad)
    return out


def prefill(params, batch, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX,
            pad_to: Optional[int] = None):
    """Build decode state from a full prompt. Returns (cache, last_logits)."""
    dt = jnp.dtype(cfg.dtype)
    cache: Dict[str, Any] = {}
    if cfg.enc_dec:
        frames = batch["frames"].astype(dt)
        enc_x = frames + sinusoid_pos_emb(frames.shape[1], cfg.d_model, dt)[None]
        enc_pos = jnp.arange(frames.shape[1])
        enc_out, _, _ = run_layers_full(params["enc_layers"], enc_x, cfg, ctx,
                                        positions=enc_pos, causal=False, rope=False)
        enc_out = rms_norm(enc_out, params["enc_norm"], cfg.norm_eps)
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens).astype(dt)
        x = x + params["dec_pos_embed"][: x.shape[1]].astype(dt)[None]
        positions = jnp.arange(x.shape[1])
        x, _, layer_caches = run_layers_full(params["layers"], x, cfg, ctx,
                                             positions=positions, causal=True,
                                             rope=False, cross_src=enc_out,
                                             build_cache=True)
        cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    else:
        x, positions = _embed_in(params, batch, cfg, ctx)
        prefix = x.shape[1] - batch["tokens"].shape[1] if cfg.vlm else 0
        x, _, layer_caches = run_layers_full(params["layers"], x, cfg, ctx,
                                             positions=positions, causal=True,
                                             prefix=prefix, build_cache=True)
        cache["pos"] = jnp.asarray(x.shape[1], jnp.int32)
        if pad_to is not None:
            pad_to = pad_to + prefix  # pad_to counts TEXT positions
    cache["layers"] = _pad_cache_seq(layer_caches, cfg, pad_to)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x, cfg, ctx)
    return cache, logits


def decode_step(params, cache, batch, cfg: ArchConfig, ctx: ShardingCtx = NULL_CTX):
    """One decode step for the whole batch. Returns (logits, new_cache)."""
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]  # [B, 1]
    pos = cache["pos"]
    x = embed_lookup(params["embed"], tokens).astype(dt)
    if cfg.vlm:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.enc_dec:
        max_dec = params["dec_pos_embed"].shape[0]
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos_embed"], jnp.minimum(pos, max_dec - 1), 1, axis=0
        ).astype(dt)[None, 0:1]
    x, new_layer_caches = run_layers_decode(params["layers"], cache["layers"],
                                            x, cfg, ctx, pos=pos)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x, cfg, ctx)
    new_cache = {"pos": pos + 1, "layers": new_layer_caches}
    return logits, new_cache


# -------------------------------------------------------------- cache specs


def cache_specs(cfg: ArchConfig, shape: ShapeSuite) -> Dict[str, Any]:
    """Abstract decode-cache tree (ParamSpec) for dry-run input construction."""
    b = shape.global_batch
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    dt = cfg.dtype
    layer: Dict[str, ParamSpec] = {}
    if cfg.has_attention:
        s_kv = cache_seq_len(cfg, shape)
        seq_axis = "seq" if (cfg.sliding_window and s_kv <= cfg.sliding_window) else "kv_seq"
        layer["k"] = ParamSpec((L, b, s_kv, cfg.kv_heads, hd),
                               ("layers", "batch", seq_axis, "kv_heads", "head_dim"), dt)
        layer["v"] = ParamSpec((L, b, s_kv, cfg.kv_heads, hd),
                               ("layers", "batch", seq_axis, "kv_heads", "head_dim"), dt)
    if cfg.enc_dec:
        s_enc = shape.seq_len
        layer["cross_k"] = ParamSpec((L, b, s_enc, cfg.kv_heads, hd),
                                     ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), dt)
        layer["cross_v"] = ParamSpec((L, b, s_enc, cfg.kv_heads, hd),
                                     ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), dt)
    if cfg.ssm or cfg.hybrid:
        shapes = ssm_cache_shape(cfg, b)
        layer["h"] = ParamSpec((L,) + shapes["h"][0],
                               ("layers", "batch", "ssm_heads", None, "ssm_state"),
                               jnp.float32)
        layer["conv"] = ParamSpec((L,) + shapes["conv"][0],
                                  ("layers", "batch", "width", "conv_dim"), dt)
    return {
        "pos": ParamSpec((), (), jnp.int32),
        "layers": layer,
    }

"""Logical-axis sharding rules (MaxText-style) with divisibility guards.

Every parameter/activation dimension carries a *logical* axis name; rules map
logical names to mesh axes. A dimension is sharded only when its size divides
the mesh-axis extent and the mesh axis is not already consumed by an earlier
dimension of the same tensor — otherwise it silently falls back to replication
(required for e.g. paligemma's kv_heads=1 on a 16-way model axis).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable `shard_map` (jax compatibility floor: 0.4.35).

    jax >= 0.6 exposes `jax.shard_map` with `check_vma`; jax 0.4.x has
    `jax.experimental.shard_map.shard_map` with the same knob named
    `check_rep`. All manual-collective paths (decode attention, MoE EP,
    compressed psum) go through this wrapper.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),   # data parallel (pod axis extends DP across pods)
    "fsdp": "data",             # ZeRO/FSDP parameter+optimizer storage sharding
    "embed": "data",            # alias of fsdp for embedding-dim storage
    "vocab": "model",           # column-parallel embedding / logits
    "heads": "model",           # tensor-parallel attention heads
    "kv_heads": "model",
    "mlp": "model",             # tensor-parallel FFN width
    "experts": "model",         # expert parallelism
    "kv_seq": "model",          # context parallelism of decode KV caches
    "d_inner": "model",         # SSM inner width tensor parallelism
    "conv_dim": "model",
    # unsharded logical axes
    "layers": None,
    "seq": None,
    "head_dim": None,
    "ssm_state": None,
    "ssm_heads": None,
    "chunk": None,
    "width": None,
    "stack": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Mesh context handed to models/runtime. mesh=None -> single-device paths."""

    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, AxisVal]] = None
    use_shard_map: bool = True  # manual paths (decode attention, MoE EP)

    @property
    def axis_sizes(self) -> Dict[str, int]:
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def resolved_rules(self) -> Dict[str, AxisVal]:
        rules = dict(DEFAULT_RULES)
        if self.rules:
            rules.update(self.rules)
        # Drop mesh axes that do not exist on this mesh (e.g. "pod" single-pod).
        names = set(self.mesh.axis_names) if self.mesh is not None else set()

        def _filter(v: AxisVal) -> AxisVal:
            if v is None:
                return None
            if isinstance(v, str):
                return v if v in names else None
            kept = tuple(a for a in v if a in names)
            return kept if kept else None

        return {k: _filter(v) for k, v in rules.items()}

    def axis_size(self, mesh_axes: AxisVal) -> int:
        if mesh_axes is None or self.mesh is None:
            return 1
        sizes = self.axis_sizes
        if isinstance(mesh_axes, str):
            return sizes[mesh_axes]
        return math.prod(sizes[a] for a in mesh_axes)

    # ------------------------------------------------------------- spec build
    def spec(self, axes: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical `axes` (guarded by `shape` divisibility)."""
        if self.mesh is None:
            return P()
        rules = self.resolved_rules()
        used: set = set()
        entries = []
        for i, name in enumerate(axes):
            mesh_axes = rules.get(name) if name else None
            if mesh_axes is None:
                entries.append(None)
                continue
            tup = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            tup = tuple(a for a in tup if a not in used)
            if not tup:
                entries.append(None)
                continue
            extent = math.prod(self.axis_sizes[a] for a in tup)
            if shape is not None and shape[i] % extent != 0:
                entries.append(None)  # replicate: not evenly divisible
                continue
            used.update(tup)
            entries.append(tup[0] if len(tup) == 1 else tup)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def named(self, axes: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def constrain(self, x, axes: Sequence[Optional[str]]):
        """with_sharding_constraint guarded for mesh-less runs."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(axes, x.shape))
        )


NULL_CTX = ShardingCtx(mesh=None)


def tree_specs(ctx: ShardingCtx, spec_tree, shape_tree) -> "jax.tree_util.PyTreeDef":
    """Map a pytree of logical-axes tuples + matching shapes to PartitionSpecs."""
    return jax.tree.map(
        lambda axes, shp: ctx.spec(axes, shp),
        spec_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(ctx: ShardingCtx, spec_tree, shape_tree):
    if ctx.mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s),
        tree_specs(ctx, spec_tree, shape_tree),
        is_leaf=lambda x: isinstance(x, P),
    )

"""whisper-medium [audio] — enc-dec transformer backbone; the conv frontend is a
STUB (input_specs() provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356; unverified",
    n_layers=24,           # decoder layers
    n_enc_layers=24,       # encoder layers
    d_model=1024,
    n_heads=16,
    kv_heads=16,           # full MHA
    d_ff=4096,
    vocab=51865,
    use_bias=True,
    enc_dec=True,
    dec_ratio=4,           # decoder seq = seq_len // 4
    tie_embeddings=True,
)

"""paligemma-3b [vlm] — SigLIP + gemma backbone; the SigLIP frontend is a STUB
(input_specs() provides precomputed patch embeddings for the prefix).
[arXiv:2407.07726; hf]

Gemma-2B decoder dims: 18L, d_model 2048, 8 heads with head_dim 256 (q width
2048), MQA kv=1, d_ff 16384.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726; hf",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    vlm=True,
    prefix_len=256,        # SigLIP 224px/14 -> 256 patch positions
    tie_embeddings=True,
)

"""Registry of assigned architectures (``--arch <id>``)."""
from repro.configs.base import ArchConfig
from repro.configs.shapes import (
    SHAPES,
    ShapeSuite,
    ALL_SHAPE_NAMES,
    batch_specs,
    cache_seq_len,
    cell_supported,
    decode_batch_specs,
    token_split,
)

from repro.configs.granite_3_2b import CONFIG as _granite_3_2b
from repro.configs.command_r_plus_104b import CONFIG as _command_r_plus_104b
from repro.configs.internlm2_20b import CONFIG as _internlm2_20b
from repro.configs.yi_6b import CONFIG as _yi_6b
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite_moe_1b_a400m
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe_30b_a3b
from repro.configs.mamba2_130m import CONFIG as _mamba2_130m
from repro.configs.hymba_1_5b import CONFIG as _hymba_1_5b
from repro.configs.whisper_medium import CONFIG as _whisper_medium
from repro.configs.paligemma_3b import CONFIG as _paligemma_3b

REGISTRY = {
    c.name: c
    for c in (
        _granite_3_2b,
        _command_r_plus_104b,
        _internlm2_20b,
        _yi_6b,
        _granite_moe_1b_a400m,
        _qwen3_moe_30b_a3b,
        _mamba2_130m,
        _hymba_1_5b,
        _whisper_medium,
        _paligemma_3b,
    )
}

ALL_ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = [
    "ArchConfig",
    "ShapeSuite",
    "SHAPES",
    "REGISTRY",
    "ALL_ARCH_NAMES",
    "ALL_SHAPE_NAMES",
    "get_config",
    "batch_specs",
    "decode_batch_specs",
    "cache_seq_len",
    "cell_supported",
    "token_split",
]

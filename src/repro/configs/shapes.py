"""Shape suites assigned to the LM family, plus abstract input specs.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every model
input of the step function that the (arch x shape) cell lowers:

  * train_4k     -> train_step(state, batch)        batch = {tokens, targets [, frames/patches]}
  * prefill_32k  -> prefill_step(params, batch)     one-shot prefill building the KV cache
  * decode_32k   -> decode_step(params, cache, batch)  one new token against a seq_len cache
  * long_500k    -> decode_step (sub-quadratic archs only)

No device allocation happens here — weak-type-correct, shardable stand-ins only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSuite] = {
    "train_4k": ShapeSuite("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSuite("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSuite("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSuite("long_500k", 524_288, 1, "decode"),
}


def cell_supported(arch: ArchConfig, shape: ShapeSuite) -> Tuple[bool, str]:
    """Whether this (arch x shape) cell is defined, and why not if skipped."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{arch.name} is pure full-attention (skip per spec, see DESIGN.md)"
        )
    return True, ""


def _f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def token_split(arch: ArchConfig, seq_len: int) -> Tuple[int, int]:
    """(frontend_positions, text_positions) for stub-frontend archs."""
    if arch.enc_dec:
        return seq_len, max(seq_len // arch.dec_ratio, 8)
    if arch.vlm:
        prefix = min(arch.prefix_len, seq_len // 2)
        return prefix, seq_len - prefix
    return 0, seq_len


def batch_specs(arch: ArchConfig, shape: ShapeSuite) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for a full forward over `seq_len` (train / prefill)."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(arch.dtype)
    front, text = token_split(arch, s)
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": _f((b, text), jnp.int32),
        "targets": _f((b, text), jnp.int32),
        "positions": _f((b, text), jnp.int32),
    }
    if arch.enc_dec:
        # Stub conv frontend: precomputed frame embeddings.
        specs["frames"] = _f((b, front, arch.d_model), dt)
    elif arch.vlm:
        # Stub SigLIP frontend: precomputed patch embeddings.
        specs["patches"] = _f((b, front, arch.d_model), dt)
    return specs


def decode_batch_specs(arch: ArchConfig, shape: ShapeSuite) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one decode step (one new token per sequence)."""
    b = shape.global_batch
    return {
        "tokens": _f((b, 1), jnp.int32),
        "positions": _f((b, 1), jnp.int32),
    }


def cache_seq_len(arch: ArchConfig, shape: ShapeSuite) -> int:
    """Per-layer attention KV length held by the decode cache."""
    if arch.sliding_window:
        return min(arch.sliding_window, shape.seq_len)
    return shape.seq_len


ALL_SHAPE_NAMES = tuple(SHAPES)

"""command-r-plus-104b [dense] — GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    kv_heads=8,
    d_ff=33792,
    vocab=256000,
    use_bias=False,
    tie_embeddings=True,
)

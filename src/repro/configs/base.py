"""Architecture configuration schema.

Every assigned architecture is expressed as an ``ArchConfig``. The *full*
configs (exact published dims) are exercised only through the dry-run
(ShapeDtypeStruct, no allocation); ``reduced()`` derives a small same-family
config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity ------------------------------------------------------------
    name: str = ""
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""       # provenance tag from the assignment table

    # transformer backbone --------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    kv_heads: int = 0
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 0              # dense FFN width (for MoE: dense path unused)
    vocab: int = 0
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    sliding_window: int = 0    # 0 = full attention

    # MoE ------------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba-2 SSD) ------------------------------------------------------
    ssm: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    # §Perf: shard-aligned split projections + head-dim TP for the SSD
    # (joint in_proj slicing at non-shard boundaries forces GSPMD permutes)
    ssm_split_proj: bool = False

    # hybrid (parallel attn + ssm heads, Hymba-style) ------------------------
    hybrid: bool = False

    # encoder-decoder (Whisper backbone; conv frontend is a stub) ------------
    enc_dec: bool = False
    n_enc_layers: int = 0
    dec_ratio: int = 4         # decoder seq = seq_len // dec_ratio

    # VLM (PaliGemma backbone; SigLIP frontend is a stub) ---------------------
    vlm: bool = False
    prefix_len: int = 0        # number of patch-embedding positions

    # runtime knobs -----------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"      # storage dtype (serving: bfloat16)
    cache_update: str = "full"        # decode KV write: full | row (§Perf)
    scan_layers: bool = True          # False for dry-run (exact cost analysis)
    remat: bool = True
    remat_policy: str = "nothing"     # nothing | dots | none
    attn_impl: str = "auto"           # auto | naive | chunked
    attn_chunk: int = 1024
    use_kernels: bool = False         # Pallas kernels (TPU); jnp refs otherwise
    moe_impl: str = "auto"            # auto | dense | ep (expert-parallel a2a)

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (O(seq) or better)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers [+ head])."""
        hd = self.resolved_head_dim
        embed = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        attn = (
            self.d_model * self.n_heads * hd          # q
            + 2 * self.d_model * self.kv_heads * hd   # k, v
            + self.n_heads * hd * self.d_model        # o
        )
        if self.moe:
            ffn = self.n_experts * 3 * self.d_model * self.d_ff_expert
            ffn += self.d_model * self.n_experts      # router
        else:
            ffn = 3 * self.d_model * self.d_ff
        ssm = 0
        if self.ssm or self.hybrid:
            di, ns = self.d_inner, self.ssm_state
            ssm = (
                self.d_model * (2 * di + 2 * ns + self.ssm_heads)  # in_proj
                + (di + 2 * ns) * self.conv_width                  # conv
                + di * self.d_model                                # out_proj
                + 3 * self.ssm_heads                               # A, dt_bias, D
            )
        per_layer = 2 * self.d_model  # norms
        if self.family == "ssm":
            per_layer += ssm
        elif self.hybrid:
            per_layer += attn + ffn + ssm
        else:
            per_layer += attn + ffn
        n_l = self.n_layers + (self.n_enc_layers if self.enc_dec else 0)
        cross = 0
        if self.enc_dec:  # decoder cross-attention blocks
            cross = self.n_layers * (attn + self.d_model)
        return embed + head + n_l * per_layer + cross

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.n_params()
        dense_like = self.replace(
            moe=False, d_ff=self.top_k * self.d_ff_expert, n_experts=0
        )
        return dense_like.n_params() + self.n_layers * self.d_model * self.n_experts

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return self.replace(
            name=self.name + "-smoke",
            n_layers=2,
            n_enc_layers=2 if self.enc_dec else 0,
            d_model=64,
            n_heads=4,
            kv_heads=max(1, min(self.kv_heads, 2)),
            head_dim=16 if self.head_dim else 0,
            d_ff=128,
            d_ff_expert=32 if self.moe else 0,
            n_experts=4 if self.moe else 0,
            top_k=2 if self.moe else 0,
            vocab=256,
            ssm_state=16 if (self.ssm or self.hybrid) else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            prefix_len=8 if self.vlm else 0,
            sliding_window=32 if self.sliding_window else 0,
            attn_chunk=32,
            scan_layers=True,
        )

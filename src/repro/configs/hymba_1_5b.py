"""hymba-1.5b [hybrid] — parallel attn + mamba heads within each layer.
[arXiv:2411.13676; hf]

Attention heads run sliding-window (Hymba uses SWA in all but 3 layers; we use
SWA uniformly, noted in DESIGN.md) which keeps the arch sub-quadratic and
eligible for the 500k-token decode shape.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676; hf",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    kv_heads=5,
    d_ff=5504,
    vocab=32001,
    hybrid=True,
    ssm=True,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    sliding_window=1024,
    tie_embeddings=True,
)

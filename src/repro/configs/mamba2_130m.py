"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060; unverified",
    n_layers=24,
    d_model=768,
    n_heads=0,
    kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=True,
)

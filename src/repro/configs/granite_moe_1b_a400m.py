"""granite-moe-1b-a400m [moe] — 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    kv_heads=8,
    d_ff=512,           # per-expert FFN width
    vocab=49155,
    moe=True,
    n_experts=32,
    top_k=8,
    d_ff_expert=512,
    tie_embeddings=True,
)

"""qwen3-moe-30b-a3b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

Note: Qwen3 uses an explicit head_dim=128 (q width 4096 > d_model 2048),
matching the HF config.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    kv_heads=4,
    head_dim=128,
    d_ff=768,           # per-expert FFN width
    vocab=151936,
    moe=True,
    n_experts=128,
    top_k=8,
    d_ff_expert=768,
    rope_theta=1_000_000.0,
)

"""Calibrated performance model of CoroAMU's evaluation (paper §V-§VI).

The paper measures speedup on an FPGA-emulated disaggregated-memory RISC-V
SoC (NANHU "NH-G", Table I) with dialable far-memory latency. No such knob
exists in this container, so the reproduction-of-record is this model: a
steady-state throughput/queueing model of the five execution configurations
over the eight benchmarks, built from the paper's published constants
(Table I microarchitecture, Fig. 13 instruction expansions, Fig. 16 MLP) and
calibrated so the paper's NUMERIC claims hold:

  * Full-system averages 3.39x @200ns / 4.87x @800ns (geomean, 8 benches)
  * GUPS up to ~29x @200ns and ~59.8x @800ns
  * x86 compiler study: hand coroutines 1.40x/2.01x (local/NUMA) vs
    CoroAMU-S 2.11x/2.78x => 1.51x relative
  * CoroAMU-D loses >15% of cycles to scheduler branch mispredicts
  * MLP: serial < 5, prefetch-based < 20 (MSHR-capped), CoroAMU ~64

Per-bench bars are not numerically specified in the text, so bench profiles
were solved (grid search) to satisfy the aggregates plus the paper's
qualitative per-bench statements (GUPS/BFS exceptional; STREAM/IS/lbm
bandwidth-bound and weak, serial-better at 100ns; coroutines switch on every
tagged access, §VI-A).

Model, per iteration (steady state, Little's law):

  serial = max(instr/IPC + local_hits + misses*lat/overlap, bytes/bw)
  coro   = max(instr*expansion/IPC + local_hits + switches*(switch+ctx)
               [+ switches*mispredict  (CoroAMU-D)],
               misses*lat/min(n_coros, inflight_cap),
               bytes/bw)
  MLP    = misses*lat/time  (emergent)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from repro.core.machine import machine_profile

# The emulated NH-G SoC is a machine like any other: its clock and
# far-memory bandwidth come from the shared `core.machine` profile table
# (paper: 3GHz, 100ns-1us far memory) and are cross-checked against the
# MicroArch calibration below in `calibration_check`.
_NHG = machine_profile("nh-g")
GHZ = _NHG.clock_ghz


@dataclasses.dataclass(frozen=True)
class MicroArch:
    """NH-G core (paper Table I); SKYLAKE for the Fig. 11 x86 study."""

    ipc: float = 2.5                  # sustained, 4-wide decode
    lsq_overlap: float = 16.0         # max OoO overlap of independent misses
    prefetcher_overlap: float = 32.0  # ... with L2 BOP help on stride streams
    mshr: int = 16                    # L1 MSHRs: prefetch in-flight cap
    amu_inflight: float = 56.0        # effective AMU in-flight (Fig.16: ~64 peak)
    local_hit: float = 35.0           # local L2/LLC hit cost (cycles)
    switch_cost_handwritten: float = 30.0  # C++20 coroutine switch
    switch_cost_compiler: float = 14.0     # CoroAMU-S codegen, prefetch
    switch_cost_amu: float = 10.0          # CoroAMU-D (getfin scheduler)
    switch_cost_bafin: float = 4.0         # 2 predicted jumps + 3 ALU ops
    mispredict_penalty: float = 14.0       # indirect-jump miss (getfin)
    bw_bytes_per_cycle: float = 16.0       # far-memory bandwidth
    prefetch_pollution: float = 0.012      # per-coroutine L1 conflict slope


NH_G = MicroArch(bw_bytes_per_cycle=_NHG.hbm_bw / (GHZ * 1e9))
SKYLAKE = MicroArch(ipc=3.2, mshr=12, bw_bytes_per_cycle=32.0,
                    switch_cost_handwritten=24.0, switch_cost_compiler=10.0,
                    local_hit=30.0, prefetch_pollution=0.008)


def calibration_check() -> None:
    """Cross-check the MicroArch calibration against the shared `nh-g`
    machine profile: the far-memory bandwidth the queueing model charges
    (bytes/cycle x clock) must be the profile's `hbm_bw`, the sustained
    instruction rate must be the profile's `peak_flops`, and the AMU's
    effective in-flight window must fit the profile's request slots
    (Fig. 16: MLP peaks ~64). Raises AssertionError on drift."""
    bw = NH_G.bw_bytes_per_cycle * GHZ * 1e9
    assert abs(bw - _NHG.hbm_bw) < 1e-6 * _NHG.hbm_bw, (bw, _NHG.hbm_bw)
    ips = NH_G.ipc * GHZ * 1e9
    assert abs(ips - _NHG.peak_flops) < 1e-6 * _NHG.peak_flops, (
        ips, _NHG.peak_flops)
    assert NH_G.amu_inflight <= _NHG.request_slots, (
        NH_G.amu_inflight, _NHG.request_slots)
    assert NH_G.mshr < _NHG.request_slots  # the paper's MSHR-vs-slots gap


@dataclasses.dataclass(frozen=True)
class BenchProfile:
    """Per-iteration workload characterization (paper Table II).

    Values solved against the paper's aggregate + qualitative constraints
    (see module docstring); `stride` marks benches whose serial baseline
    benefits from the L2 Best-Offset Prefetcher (Table I).
    """

    name: str
    instr: float
    accesses: float          # tagged far-memory requests / iteration
    locality: float          # fraction hitting local cache
    overlap: float           # serial OoO(+prefetcher) overlap of misses
    coalesce_spatial: float  # fraction merged into coarse (span) requests
    coalesce_indep: float    # fraction merged via aset groups
    context_words: int       # live context, conventional codegen
    context_words_opt: int   # after private/shared/sequential analysis
    bytes: float             # far-memory bytes / iteration
    stride: bool = False
    # serial overlap measured on the x86 host (Fig. 11 study) — the Skylake
    # hierarchy overlaps misses differently than NH-G
    overlap_x86: float = 4.0


BENCHES: Dict[str, BenchProfile] = {
    "GUPS": BenchProfile("GUPS", 10, 1.0, 0.00, 1.0, 0.0, 0.00, 4, 2, 16, overlap_x86=1.5),
    "BS": BenchProfile("BS", 8, 1.0, 0.00, 24.0, 0.0, 0.00, 6, 3, 8, overlap_x86=1.0),
    "BFS": BenchProfile("BFS", 20, 4.0, 0.00, 6.0, 0.0, 0.30, 8, 4, 24, overlap_x86=2.0),
    "STREAM": BenchProfile("STREAM", 10, 3.0, 0.50, 20.0, 0.9, 0.00, 6, 2, 24, stride=True, overlap_x86=12.0),
    "HJ": BenchProfile("HJ", 24, 4.0, 0.30, 24.0, 0.0, 0.40, 10, 4, 48, overlap_x86=3.0),
    "mcf": BenchProfile("mcf", 9, 4.0, 0.00, 16.0, 0.0, 0.35, 12, 6, 48, stride=True, overlap_x86=1.5),
    "lbm": BenchProfile("lbm", 220, 19.0, 0.90, 10.0, 0.85, 0.00, 16, 6, 300, stride=True, overlap_x86=2.0),
    "IS": BenchProfile("IS", 8, 4.0, 0.70, 10.0, 0.5, 0.00, 6, 3, 24, stride=True, overlap_x86=16.0),
}

VARIANTS = ("serial", "coroutine", "coroamu-s", "coroamu-d", "coroamu-full")

# Fig. 13 dynamic-instruction expansion vs serial
EXPANSION = {
    "serial": 1.0,
    "coroutine": 4.5,
    "coroamu-s": 6.70,
    "coroamu-d": 5.98,
    "coroamu-full": 3.91,
}

PREFETCH_VARIANTS = ("coroutine", "coroamu-s")


@dataclasses.dataclass
class SimResult:
    variant: str
    bench: str
    latency_ns: float
    n_coros: int
    cycles_per_iter: float
    mlp: float
    breakdown: Dict[str, float]
    inflight_cap: float


def _ov(b: BenchProfile, ua: MicroArch) -> float:
    cap = ua.prefetcher_overlap if b.stride else ua.lsq_overlap
    ov = b.overlap_x86 if ua is SKYLAKE else b.overlap
    return min(ov, cap)


def simulate(variant: str, bench: BenchProfile, *, latency_ns: float,
             n_coros: int = 96, ua: MicroArch = NH_G,
             ctx_opt: bool | None = None,
             coalesce: bool | None = None) -> SimResult:
    """ctx_opt/coalesce override the variant defaults (Fig. 15 ablations)."""
    b = bench
    lat = latency_ns * GHZ
    m = b.accesses * (1.0 - b.locality)
    ov = _ov(b, ua)
    local = b.accesses * b.locality * ua.local_hit / ov
    bw = b.bytes / ua.bw_bytes_per_cycle

    if variant == "serial":
        compute = b.instr / ua.ipc + local
        stall = m * lat / ov
        total = max(compute + stall, bw)
        return SimResult(variant, b.name, latency_ns, 1, total,
                         m * lat / total,
                         {"compute": compute / total, "scheduler": 0.0,
                          "context": 0.0, "mispredict": 0.0,
                          "stall": max(1.0 - compute / total, 0.0)},
                         ov)

    if variant == "coroutine":
        switch_cost = ua.switch_cost_handwritten
    elif variant == "coroamu-s":
        switch_cost = ua.switch_cost_compiler
    elif variant == "coroamu-d":
        switch_cost = ua.switch_cost_amu
    elif variant == "coroamu-full":
        switch_cost = ua.switch_cost_bafin
    else:
        raise ValueError(variant)
    if ctx_opt is None:
        ctx_opt = variant == "coroamu-full"
    if coalesce is None:
        coalesce = variant == "coroamu-full"
    ctx_words = b.context_words_opt if ctx_opt else b.context_words

    # coroutines suspend on every tagged access (§VI-A); -Full coalesces
    switches = b.accesses
    if coalesce:
        switches = b.accesses * max(1.0 - (b.coalesce_spatial + b.coalesce_indep), 0.15)

    instr = b.instr * EXPANSION[variant]
    compute = instr / ua.ipc + local
    sched = switches * switch_cost
    ctx = switches * ctx_words  # 2 ops/word at 2 ops/cycle
    mispredict = switches * ua.mispredict_penalty if variant == "coroamu-d" else 0.0
    cpu = compute + sched + ctx + mispredict

    cap = float(ua.mshr) if variant in PREFETCH_VARIANTS else ua.amu_inflight
    inflight = min(float(n_coros), cap)
    latency_term = m * lat / max(inflight, 1.0)

    pollution = 0.0
    if variant in PREFETCH_VARIANTS:
        evicted = min(ua.prefetch_pollution * max(n_coros - 24, 0), 0.6)
        pollution = m * evicted * lat / max(ov * 4, 1)

    total = max(cpu + pollution, latency_term, bw)
    return SimResult(variant, b.name, latency_ns, n_coros, total,
                     m * lat / total,
                     {"compute": compute / total, "scheduler": sched / total,
                      "context": ctx / total, "mispredict": mispredict / total,
                      "stall": max(1.0 - (cpu + pollution) / total, 0.0)},
                     inflight)


def speedup(variant: str, bench: BenchProfile, *, latency_ns: float,
            n_coros: int = 96, ua: MicroArch = NH_G) -> float:
    s = simulate("serial", bench, latency_ns=latency_ns, ua=ua)
    v = simulate(variant, bench, latency_ns=latency_ns, n_coros=n_coros, ua=ua)
    return s.cycles_per_iter / v.cycles_per_iter


COROS_GRID = (2, 4, 8, 16, 24, 32, 48, 64, 96)


def best_coros(variant: str, bench: BenchProfile, *, latency_ns: float,
               ua: MicroArch = NH_G, grid=COROS_GRID) -> int:
    return max(grid, key=lambda n: speedup(variant, bench, latency_ns=latency_ns,
                                           n_coros=n, ua=ua))


def geomean(xs: List[float]) -> float:
    return math.exp(sum(math.log(max(x, 1e-12)) for x in xs) / len(xs))


def average_speedup(variant: str, *, latency_ns: float, n_coros: int = 96,
                    ua: MicroArch = NH_G, tune_coros: bool = False) -> float:
    sps = []
    for b in BENCHES.values():
        n = best_coros(variant, b, latency_ns=latency_ns, ua=ua) if tune_coros else n_coros
        sps.append(speedup(variant, b, latency_ns=latency_ns, n_coros=n, ua=ua))
    return geomean(sps)

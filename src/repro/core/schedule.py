"""Scheduling policies and the latency-aware depth solver (CoroAMU §III-D).

The paper contrasts:
  static   - fixed launch order tuned for ONE latency; degrades when latency
             varies (prefetch-distance mismatch) and is capped by MSHRs.
  dynamic  - resume whichever coroutine's data arrived (getfin/bafin);
             adapts to variable latency, capped only by SPM request slots.

TPU adaptation (DESIGN.md §2.1): the DMA completion oracle exists at issue
time, so the dynamic scheduler collapses to a rotation whose DEPTH must cover
the worst-case latency — adaptivity moves into `solve_depth`, which takes the
latency bound as an input instead of polling at run time. `adaptive_depth`
re-solves from observed latency samples (the run-time feedback loop the
paper's Return Block implements in hardware).

Every hardware constant lives in `core.machine` (one `MachineModel`, many
profiles — the paper's latency dial as `REPRO_MACHINE=v5e-far-800ns`). The
solver reads the ACTIVE profile by default and takes `machine=` to solve
for another one; the legacy module constants (`VMEM_BYTES`,
`HBM_LATENCY_S`, `HBM_BW`, `PEAK_FLOPS`, `REQUEST_SLOTS`) are thin aliases
of the active profile via module `__getattr__`, kept for callers that
snapshot them (tests, benchmarks).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.machine import MachineModel, get_machine


@dataclasses.dataclass(frozen=True)
class TileProfile:
    """One coroutine's footprint and work."""

    tile_bytes: int              # bytes DMA'd per tile (the context data)
    flops_per_tile: float        # compute after resumption
    private_bytes: int = 0       # extra per-slot context (core.context)
    shared_bytes: int = 0        # depth-independent VMEM residents


def tile_compute_s(p: TileProfile, *,
                   machine: Optional[MachineModel] = None) -> float:
    m = machine or get_machine()
    return p.flops_per_tile / m.peak_flops


def tile_transfer_s(p: TileProfile, *,
                    machine: Optional[MachineModel] = None) -> float:
    m = machine or get_machine()
    return p.tile_bytes / m.hbm_bw


def solve_depth(p: TileProfile, *, machine: Optional[MachineModel] = None,
                latency_s: Optional[float] = None,
                vmem_budget: Optional[int] = None,
                slot_limit: Optional[int] = None,
                vmem_cap: Optional[int] = None) -> int:
    """Smallest depth that hides the latency, capped by VMEM and slot count.

    `machine` defaults to the active `core.machine` profile; `latency_s` /
    `vmem_budget` / `slot_limit` default to that model's fields and override
    them individually when given (the latency dial, a tighter budget).

    Hiding condition (paper §II insight, adapted): while one tile's DMA is in
    flight (latency + transfer), the other depth-1 slots must keep the
    machine busy. A slot's steady-state service time is bounded below by its
    compute AND by its own transfer (in-flight DMAs overlap, so transfer
    time is supplied concurrently — the paper's MLP argument), giving

        (depth-1) * max(t_compute, t_transfer) >= latency + t_transfer.

    For compute-rich tiles this reduces to the classic compute-hiding bound;
    for pure data movement it solves to the MLP that saturates HBM bandwidth
    at the given latency instead of diverging. `slot_limit` is the SPM
    request-slot bound the paper's dynamic scheduler is capped by (unlike
    the static baseline's MSHR cap it is a property of the pipeline's own
    context arena, not the core) — it also bounds the unrolled warmup code.

    `vmem_cap` overrides the profile-derived capacity cap with an externally
    classified one: `core.autotune.choose_depth` passes
    `context.max_depth(spec.vars, vmem_budget)` here so the VMEM bound comes
    from the §III-B classification (private x depth, shared x 1) instead of
    the hand-filled profile byte counts.
    """
    m = machine or get_machine()
    latency_s = m.hbm_latency_s if latency_s is None else latency_s
    vmem_budget = m.vmem_bytes if vmem_budget is None else vmem_budget
    slot_limit = m.request_slots if slot_limit is None else slot_limit
    tc = max(tile_compute_s(p, machine=m), 1e-12)
    tt = tile_transfer_s(p, machine=m)
    service = max(tc, tt)
    need = math.ceil((latency_s + tt) / service) + 1
    if vmem_cap is not None:
        cap = vmem_cap
    else:
        per_slot = p.tile_bytes + p.private_bytes
        cap = max((vmem_budget - p.shared_bytes) // max(per_slot, 1), 1)
    return int(max(2, min(need, cap, slot_limit)))


def achieved_bandwidth(p: TileProfile, depth: int,
                       *, machine: Optional[MachineModel] = None,
                       latency_s: Optional[float] = None) -> float:
    """Steady-state HBM bytes/s of the pipeline at a given depth.

    Each slot cycles through issue -> in-flight(latency+transfer) -> compute.
    With `depth` slots, a tile completes every
    max(t_compute, (latency + t_transfer + t_compute)/depth).
    """
    m = machine or get_machine()
    latency_s = m.hbm_latency_s if latency_s is None else latency_s
    tc = tile_compute_s(p, machine=m)
    tt = tile_transfer_s(p, machine=m)
    period = max(tc, (latency_s + tt + tc) / depth, tt)
    return p.tile_bytes / period


def adaptive_depth(p: TileProfile, latency_samples_s: Sequence[float],
                   *, quantile: float = 0.95,
                   machine: Optional[MachineModel] = None,
                   vmem_budget: Optional[int] = None,
                   slot_limit: Optional[int] = None,
                   vmem_cap: Optional[int] = None) -> int:
    """Dynamic-scheduler analogue: re-solve depth from observed latencies."""
    if not latency_samples_s:
        return solve_depth(p, machine=machine, vmem_budget=vmem_budget,
                           slot_limit=slot_limit, vmem_cap=vmem_cap)
    xs = sorted(latency_samples_s)
    q = xs[min(int(quantile * len(xs)), len(xs) - 1)]
    return solve_depth(p, machine=machine, latency_s=q,
                       vmem_budget=vmem_budget, slot_limit=slot_limit,
                       vmem_cap=vmem_cap)


def static_prefetch_depth(p: TileProfile, *, latency_s: float,
                          machine: Optional[MachineModel] = None,
                          mshr_limit: int = 16) -> int:
    """The baseline the paper improves on: prefetch distance capped by MSHRs."""
    return min(solve_depth(p, machine=machine, latency_s=latency_s),
               mshr_limit)


_MACHINE_ALIASES = ("PEAK_FLOPS", "HBM_BW", "HBM_LATENCY_S", "VMEM_BYTES",
                    "REQUEST_SLOTS", "ICI_BW")


def __getattr__(name: str):
    # Legacy constants forward to the ACTIVE machine profile — the single
    # definition is core.machine (ISSUE-6 acceptance criterion).
    if name in _MACHINE_ALIASES:
        from repro.core import machine as _machine

        return getattr(_machine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

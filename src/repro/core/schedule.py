"""Scheduling policies and the latency-aware depth solver (CoroAMU §III-D).

The paper contrasts:
  static   - fixed launch order tuned for ONE latency; degrades when latency
             varies (prefetch-distance mismatch) and is capped by MSHRs.
  dynamic  - resume whichever coroutine's data arrived (getfin/bafin);
             adapts to variable latency, capped only by SPM request slots.

TPU adaptation (DESIGN.md §2.1): the DMA completion oracle exists at issue
time, so the dynamic scheduler collapses to a rotation whose DEPTH must cover
the worst-case latency — adaptivity moves into `solve_depth`, which takes the
latency bound as an input instead of polling at run time. `adaptive_depth`
re-solves from observed latency samples (the run-time feedback loop the
paper's Return Block implements in hardware).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

# v5e-class constants (see repro.roofline)
VMEM_BYTES = 128 * 1024 * 1024
HBM_LATENCY_S = 700e-9          # HBM round-trip seen by a DMA
HBM_BW = 819e9
PEAK_FLOPS = 197e12
# the paper's "capped only by SPM request slots": outstanding-DMA bound per
# pipeline. Also keeps the kernels' Python-unrolled warmup loops bounded.
REQUEST_SLOTS = 64


@dataclasses.dataclass(frozen=True)
class TileProfile:
    """One coroutine's footprint and work."""

    tile_bytes: int              # bytes DMA'd per tile (the context data)
    flops_per_tile: float        # compute after resumption
    private_bytes: int = 0       # extra per-slot context (core.context)
    shared_bytes: int = 0        # depth-independent VMEM residents


def tile_compute_s(p: TileProfile) -> float:
    return p.flops_per_tile / PEAK_FLOPS


def tile_transfer_s(p: TileProfile) -> float:
    return p.tile_bytes / HBM_BW


def solve_depth(p: TileProfile, *, latency_s: float = HBM_LATENCY_S,
                vmem_budget: int = VMEM_BYTES,
                slot_limit: int = REQUEST_SLOTS,
                vmem_cap: Optional[int] = None) -> int:
    """Smallest depth that hides `latency_s`, capped by VMEM and slot count.

    Hiding condition (paper §II insight, adapted): while one tile's DMA is in
    flight (latency + transfer), the other depth-1 slots must keep the
    machine busy. A slot's steady-state service time is bounded below by its
    compute AND by its own transfer (in-flight DMAs overlap, so transfer
    time is supplied concurrently — the paper's MLP argument), giving

        (depth-1) * max(t_compute, t_transfer) >= latency + t_transfer.

    For compute-rich tiles this reduces to the classic compute-hiding bound;
    for pure data movement it solves to the MLP that saturates HBM bandwidth
    at the given latency instead of diverging. `slot_limit` is the SPM
    request-slot bound the paper's dynamic scheduler is capped by (unlike
    the static baseline's MSHR cap it is a property of the pipeline's own
    context arena, not the core) — it also bounds the unrolled warmup code.

    `vmem_cap` overrides the profile-derived capacity cap with an externally
    classified one: `core.autotune.choose_depth` passes
    `context.max_depth(spec.vars, vmem_budget)` here so the VMEM bound comes
    from the §III-B classification (private x depth, shared x 1) instead of
    the hand-filled profile byte counts.
    """
    tc = max(tile_compute_s(p), 1e-12)
    service = max(tc, tile_transfer_s(p))
    need = math.ceil((latency_s + tile_transfer_s(p)) / service) + 1
    if vmem_cap is not None:
        cap = vmem_cap
    else:
        per_slot = p.tile_bytes + p.private_bytes
        cap = max((vmem_budget - p.shared_bytes) // max(per_slot, 1), 1)
    return int(max(2, min(need, cap, slot_limit)))


def achieved_bandwidth(p: TileProfile, depth: int,
                       *, latency_s: float = HBM_LATENCY_S) -> float:
    """Steady-state HBM bytes/s of the pipeline at a given depth.

    Each slot cycles through issue -> in-flight(latency+transfer) -> compute.
    With `depth` slots, a tile completes every
    max(t_compute, (latency + t_transfer + t_compute)/depth).
    """
    tc = tile_compute_s(p)
    tt = tile_transfer_s(p)
    period = max(tc, (latency_s + tt + tc) / depth, tt)
    return p.tile_bytes / period


def adaptive_depth(p: TileProfile, latency_samples_s: Sequence[float],
                   *, quantile: float = 0.95,
                   vmem_budget: int = VMEM_BYTES,
                   slot_limit: int = REQUEST_SLOTS,
                   vmem_cap: Optional[int] = None) -> int:
    """Dynamic-scheduler analogue: re-solve depth from observed latencies."""
    if not latency_samples_s:
        return solve_depth(p, vmem_budget=vmem_budget, slot_limit=slot_limit,
                           vmem_cap=vmem_cap)
    xs = sorted(latency_samples_s)
    q = xs[min(int(quantile * len(xs)), len(xs) - 1)]
    return solve_depth(p, latency_s=q, vmem_budget=vmem_budget,
                       slot_limit=slot_limit, vmem_cap=vmem_cap)


def static_prefetch_depth(p: TileProfile, *, latency_s: float,
                          mshr_limit: int = 16) -> int:
    """The baseline the paper improves on: prefetch distance capped by MSHRs."""
    return min(solve_depth(p, latency_s=latency_s), mshr_limit)

"""Request planning: coalescing and RMW dedup (CoroAMU §III-C / §III-E).

The paper's compiler merges memory requests two ways:
  1. coarse-grained: spatially-adjacent accesses become one up-to-4KB request
     (granularity in high address bits);
  2. `aset`: n independent requests bound to one ID, completing together.

On TPU, DMA descriptors must have static shapes, so coalescing quantizes:
runs of >= span rows become fixed-size span DMAs; the remainder stays as
single-row requests grouped `aset`-style under one slot semaphore. The
planner below is the host-side pass; kernels/coro_gather consumes its plan.

`dedup_rmw` is the compile-time replacement for the paper's await/asignal
locks: duplicate read-modify-write targets are pre-combined (sort +
segment-sum) so each row is written exactly once and slots can never race.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class GatherPlan:
    """Coalesced gather: `span_starts[i]` covers rows [start, start+span);
    `singles` are the remaining row ids; `order` maps concat(spans*span,
    singles) positions back to the original request order."""

    span: int
    span_starts: np.ndarray   # [n_spans] int32
    singles: np.ndarray       # [n_singles] int32
    order: np.ndarray         # [n_requests] int32 permutation into outputs
    n_requests: int

    @property
    def n_spans(self) -> int:
        return int(self.span_starts.shape[0])

    @property
    def n_singles(self) -> int:
        return int(self.singles.shape[0])

    def requests_issued(self) -> int:
        return self.n_spans + self.n_singles

    def coalescing_ratio(self) -> float:
        return self.requests_issued() / max(self.n_requests, 1)


def plan_gather(indices: np.ndarray, *, span: int = 8) -> GatherPlan:
    """Greedy span coalescing of a gather index stream.

    Detects maximal runs of consecutive row ids (in sorted order) and carves
    them into fixed-`span` DMAs; everything else is a single-row request.
    Duplicate ids are NOT deduped (a gather may legitimately re-read a row);
    they simply never coalesce with themselves.
    """
    idx = np.asarray(indices, np.int64)
    n = idx.shape[0]
    if n == 0:
        return GatherPlan(span, np.zeros(0, np.int32), np.zeros(0, np.int32),
                          np.zeros(0, np.int32), 0)
    order = np.argsort(idx, kind="stable")
    s = idx[order]
    # run boundaries: value not exactly previous+1
    new_run = np.ones(n, bool)
    new_run[1:] = s[1:] != s[:-1] + 1
    run_id = np.cumsum(new_run) - 1
    run_start_pos = np.flatnonzero(new_run)
    run_len = np.diff(np.append(run_start_pos, n))

    out_pos_sorted = np.empty(n, np.int64)  # output slot per sorted position
    span_starts = []
    singles = []
    for rs, rl in zip(run_start_pos, run_len):
        full = rl // span
        for k in range(full):
            base = len(span_starts) * span
            span_starts.append(int(s[rs + k * span]))
            for j in range(span):
                out_pos_sorted[rs + k * span + j] = base + j
        for j in range(full * span, rl):
            singles.append(int(s[rs + j]))
            out_pos_sorted[rs + j] = -len(singles)  # placeholder (negative)
    n_span_rows = len(span_starts) * span
    # fix single positions now that span count is known
    neg = out_pos_sorted < 0
    out_pos_sorted[neg] = n_span_rows + (-out_pos_sorted[neg] - 1)

    order_out = np.empty(n, np.int64)
    order_out[order] = out_pos_sorted  # original request i -> output row
    return GatherPlan(
        span,
        np.asarray(span_starts, np.int32),
        np.asarray(singles, np.int32),
        order_out.astype(np.int32),
        n,
    )


def apply_plan_reference(plan: GatherPlan, table: np.ndarray) -> np.ndarray:
    """Oracle: execute the plan with numpy (tests compare vs direct gather)."""
    parts = []
    for st in plan.span_starts:
        parts.append(table[st: st + plan.span])
    if plan.n_singles:
        parts.append(table[plan.singles])
    if parts:
        flat = np.concatenate(parts, axis=0)
    else:
        flat = np.zeros((0,) + table.shape[1:], table.dtype)
    return flat[plan.order]


def dedup_rmw(indices: np.ndarray, updates: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Combine duplicate RMW targets (await/asignal -> compile-time transform).

    Returns (unique_indices, summed_updates) such that a scatter-add of the
    result equals a scatter-add of the input, with each row touched once.
    """
    idx = np.asarray(indices)
    upd = np.asarray(updates)
    uniq, inv = np.unique(idx, return_inverse=True)
    out = np.zeros((uniq.shape[0],) + upd.shape[1:], upd.dtype)
    np.add.at(out, inv, upd)
    return uniq.astype(idx.dtype), out

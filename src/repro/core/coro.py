"""The coroutine engine: decoupled-DMA software pipelines for Pallas TPU.

This is the TPU-native realization of CoroAMU's execution model
(DESIGN.md §2). Correspondence:

  aload/astore  -> pltpu.make_async_copy(...).start()        (issue)
  getfin/bafin  -> semaphore wait on the slot being resumed   (poll/jump)
  SPM slots     -> VMEM scratch shaped (depth, *tile)         (context)
  coroutine     -> pipeline slot processing one tile
  aset n        -> n copies signalling one slot semaphore; one wait-group
  scheduler     -> modulo rotation over slots (mispredict-free by
                   construction: control flow is compile-time scheduled)

A kernel built on `coro_loop` keeps `depth` tiles in flight: while slot k's
data is crossing HBM->VMEM, slots k-1, k-2, ... are being consumed - exactly
the paper's interleaving of memory-driven coroutines.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def coro_loop(
    n_tiles: int,
    depth: int,
    issue_fn: Callable[[Any, Any], None],
    consume_fn: Callable[[Any, Any, Any], Any],
    wait_fn: Callable[[Any, Any], None],
    carry_init: Any = 0,
    *,
    grid_step: Any = None,
):
    """Run the coroutine pipeline over `n_tiles` with `depth` in flight.

    issue_fn(tile, slot)          - start the decoupled copies for `tile`
                                    into `slot` (aload/aset analogue)
    wait_fn(tile, slot)           - block until slot's copies landed (getfin)
    consume_fn(tile, slot, carry) - the coroutine body after resumption;
                                    returns updated carry

    `n_tiles`/`depth` are Python ints (grid is static); `tile`/`slot` are
    traced int32 inside the steady-state loop.

    Two drive modes share the one rotation (warmup / wait / consume /
    recycle) so no kernel re-implements the schedule:

    * fori mode (`grid_step=None`, default): the whole pipeline runs inside
      one kernel invocation via `jax.lax.fori_loop` over all tiles
      (decode_attention, moe_gmm, ssd_scan).
    * grid mode (`grid_step=pl.program_id(...)`): the Pallas grid supplies
      the tile loop — each grid step executes exactly one pipeline step for
      tile `grid_step`, relying on VMEM scratch persisting across steps.
      Warmup runs once under `pl.when(grid_step == 0)`
      (coro_gather, coro_scatter_add, stream_copy).
    """
    depth = min(depth, n_tiles)
    if depth <= 0:
        return carry_init

    def warmup():
        # launch the initial coroutine batch (paper's Init Block)
        for t in range(depth):
            issue_fn(t, t)

    def step(t, carry):
        slot = jax.lax.rem(t, depth)
        # resume the coroutine whose data has arrived (bafin: the schedule is
        # compile-time so the "jump" costs nothing)
        wait_fn(t, slot)
        carry = consume_fn(t, slot, carry)

        # recycle the slot: launch the next iteration (paper's Return Block)
        @pl.when(t + depth < n_tiles)
        def _():
            issue_fn(t + depth, slot)

        return carry

    if grid_step is None:
        warmup()
        return jax.lax.fori_loop(0, n_tiles, step, carry_init)

    @pl.when(grid_step == 0)
    def _():
        warmup()

    return step(grid_step, carry_init)


# ------------------------------------------------------------- DMA helpers


def issue_rows(hbm_ref, row_ids: Sequence, slot_buf, sem, *, rows_per_copy: int = 1):
    """aset-style group: one DMA per row id, all bound to `sem`.

    row_ids are traced int32 scalars; each copies `rows_per_copy` contiguous
    rows from `hbm_ref` into consecutive positions of `slot_buf`.
    """
    for j, r in enumerate(row_ids):
        pltpu.make_async_copy(
            hbm_ref.at[pl.ds(r, rows_per_copy)],
            slot_buf.at[pl.ds(j * rows_per_copy, rows_per_copy)],
            sem,
        ).start()


def wait_rows(slot_buf, sem, n_copies: int, *, rows_per_copy: int = 1):
    """Wait for an issue_rows group (one wait per constituent copy)."""
    for j in range(n_copies):
        pltpu.make_async_copy(
            slot_buf.at[pl.ds(j * rows_per_copy, rows_per_copy)],
            slot_buf.at[pl.ds(j * rows_per_copy, rows_per_copy)],
            sem,
        ).wait()


def issue_block(hbm_ref, start, slot_buf, sem, *, rows: int):
    """Coarse-grained request (paper §III-C case 1): one span DMA."""
    pltpu.make_async_copy(hbm_ref.at[pl.ds(start, rows)], slot_buf, sem).start()


def wait_block(slot_buf, sem):
    pltpu.make_async_copy(slot_buf, slot_buf, sem).wait()


def store_block(slot_buf, hbm_ref, start, sem, *, rows: int):
    """astore analogue: decoupled write-back VMEM -> HBM."""
    pltpu.make_async_copy(slot_buf, hbm_ref.at[pl.ds(start, rows)], sem).start()


def wait_store(slot_buf, hbm_ref, start, sem, *, rows: int):
    pltpu.make_async_copy(slot_buf, hbm_ref.at[pl.ds(start, rows)], sem).wait()

"""The coroutine engine: declarative decoupled-DMA pipelines for Pallas TPU.

This is the TPU-native realization of CoroAMU's execution model
(DESIGN.md §2). The paper's compiler takes *declared* memory operations and
derives the minimized context and schedule (§III-B/§III-C); here a kernel
declares a `CoroSpec` and the builder derives everything else.
Correspondence:

  aload         -> LoadStream            (decoupled HBM->VMEM copy group)
  astore        -> StoreStream           (decoupled VMEM->HBM write-back,
                                          drain-before-reuse + epilogue drain)
  aset n        -> stream group=n        (n copies signalling one slot
                                          semaphore; one wait-group)
  context       -> CoroSpec.vars         (core.context.VarSpec; scratch shape
                                          derived from classify(): private x
                                          depth, shared/sequential x 1)
  getfin/bafin  -> semaphore wait on the slot being resumed (poll/jump)
  SPM slots     -> VMEM scratch shaped (depth, *tile), allocated here
  coroutine     -> pipeline slot processing one tile
  scheduler     -> modulo rotation over slots (`coro_loop`; mispredict-free
                   by construction: control flow is compile-time scheduled)

A kernel built on this module keeps `depth` tiles in flight: while slot k's
data is crossing HBM<->VMEM, slots k-1, k-2, ... are being consumed — the
paper's interleaving of memory-driven coroutines. `depth=None` lets
`core.autotune.choose_depth` solve the depth from the spec's tile profile,
with the VMEM cap taken from the classified context bytes, for the active
`core.machine` profile. Every launched pipeline is wall-clocked and fed
back to `autotune.observe_pipeline` (always-on transfer telemetry) so the
adaptive re-solve learns from real runs without caller wiring.

Layering:

  CoroSpec / LoadStream / StoreStream  - the declaration (kernel authoring)
  coro_call                            - entry-point builder: resolves depth,
                                         derives scratch + semaphores, wraps
                                         pl.pallas_call, runs the pipeline
  coro_pipeline                        - the in-kernel engine (warmup /
                                         rotate / wait / consume / store
                                         drain) for hand-rolled kernels
  coro_loop                            - the bare rotation (no streams)
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import context as ctx_mod

__all__ = [
    "CoroRefs",
    "CoroSpec",
    "LoadStream",
    "StoreStream",
    "coro_call",
    "coro_loop",
    "coro_pipeline",
]


# ------------------------------------------------------------ declarations


@dataclasses.dataclass(frozen=True)
class LoadStream:
    """A decoupled input stream (aload/aset): slot buffer x depth.

    `src(ctx, tile)` returns the HBM ref-slice(s) feeding tile `tile`:
    a single slice (coarse-grained span request, §III-C case 1) or a list of
    `group` slices (an aset group — e.g. one DMA per gathered row), copied
    into consecutive `tile[0] // group`-row chunks of the slot buffer.
    """

    name: str
    tile: Tuple[int, ...]
    dtype: Any
    src: Callable[..., Any]
    group: int = 1

    def __post_init__(self):
        _check_group(self)

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.tile)) * int(np.dtype(self.dtype).itemsize)


def _check_group(stream) -> None:
    if stream.group < 1 or stream.tile[0] % stream.group:
        raise ValueError(
            f"stream {stream.name!r}: tile[0]={stream.tile[0]} must divide "
            f"into group={stream.group} equal chunks")


@dataclasses.dataclass(frozen=True)
class StoreStream:
    """A decoupled output stream (astore) with RMW drain semantics.

    The body writes the slot buffer; the builder starts the write-back DMAs
    to `dst(ctx, tile)` after the body, drains a slot's previous store
    before the body may rewrite it (tile >= depth), and drains every slot
    once more after the rotation retires (epilogue drain).
    """

    name: str
    tile: Tuple[int, ...]
    dtype: Any
    dst: Callable[..., Any]
    group: int = 1

    def __post_init__(self):
        _check_group(self)

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.tile)) * int(np.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class CoroSpec:
    """Declarative description of one coroutine kernel family.

    The builder derives from it, per depth:
      * per-slot VMEM scratch for every stream ((depth, *tile), private
        context by construction),
      * one DMA semaphore array for the loads and one for the stores,
      * scratch for every materialized `vars` entry, shaped from
        `core.context.classify()` (private x depth, shared/sequential x 1),
      * the tile's `TileProfile` (DMA bytes + flops) for the depth solver.
    """

    name: str
    loads: Tuple[LoadStream, ...] = ()
    stores: Tuple[StoreStream, ...] = ()
    vars: Tuple[ctx_mod.VarSpec, ...] = ()
    flops_per_tile: float = 0.0

    def __post_init__(self):
        names = [s.name for s in self.loads] + [s.name for s in self.stores] \
            + [v.name for v in self.vars]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stream/var names in spec: {names}")

    # ---- derived context (paper §III-B)

    def stream_vars(self) -> Tuple[ctx_mod.VarSpec, ...]:
        """Every stream slot is private context: one copy per in-flight tile."""
        return tuple(
            ctx_mod.VarSpec(name=s.name, nbytes=s.nbytes,
                            shape=tuple(s.tile), dtype=s.dtype)
            for s in (*self.loads, *self.stores)
        )

    def all_vars(self) -> Tuple[ctx_mod.VarSpec, ...]:
        return (*self.stream_vars(), *self.vars)

    def context_bytes(self, depth: int, *, baseline: bool = False) -> int:
        """Classified VMEM working set at `depth` (Fig. 15's comparison)."""
        return ctx_mod.context_bytes(self.all_vars(), depth, baseline=baseline)

    def tile_bytes(self) -> int:
        """HBM traffic per tile: every load and store stream moves its tile."""
        return sum(s.nbytes for s in (*self.loads, *self.stores))

    def profile(self):
        from repro.core.schedule import TileProfile  # local: avoid eager dep
        return TileProfile(tile_bytes=self.tile_bytes(),
                           flops_per_tile=float(self.flops_per_tile))

    # ---- derived allocation

    def scratch_shapes(self, depth: int) -> list:
        """The scratch list a kernel needs, in the canonical order
        [load slots..., store slots..., load sem, store sem, vars...]."""
        shapes: list = [
            pltpu.VMEM((depth, *s.tile), s.dtype)
            for s in (*self.loads, *self.stores)
        ]
        if self.loads:
            shapes.append(pltpu.SemaphoreType.DMA((depth,)))
        if self.stores:
            shapes.append(pltpu.SemaphoreType.DMA((depth,)))
        for v in self.materialized_vars():
            if ctx_mod.classify(v) is ctx_mod.VarClass.PRIVATE:
                shapes.append(pltpu.VMEM((depth, *v.shape), v.dtype))
            else:  # shared / sequential: one copy regardless of depth
                shapes.append(pltpu.VMEM(tuple(v.shape), v.dtype))
        return shapes

    def materialized_vars(self) -> Tuple[ctx_mod.VarSpec, ...]:
        return tuple(v for v in self.vars if v.shape is not None)


class CoroRefs:
    """Attribute namespace handed to spec callbacks: operand refs by their
    declared name, stream slot buffers and materialized vars by stream/var
    name."""

    def __init__(self, mapping):
        self.__dict__.update(mapping)


def _observe_pipeline(spec: "CoroSpec", t0: float, out, n_tiles: int,
                      depth: int) -> None:
    """Always-on transfer telemetry (ISSUE-6): wall-clock the launched
    pipeline and feed `autotune.observe_pipeline` (which drops the compile
    warmup and records wall/tiles as a per-tile transfer sample). Skipped
    under jit tracing — there is no wall clock to observe — and when
    `autotune.set_telemetry(False)`/``REPRO_TELEMETRY=0`` turned it off.

    The same wall clock becomes one ``pipeline:<kernel>`` span on the
    observability tracer (ISSUE-8), carrying the depth / n_tiles /
    classified context-bytes attributes DESIGN.md §2.5 documents — a null
    no-op when tracing is off."""
    from repro.core import autotune  # local: mirror coro_call's lazy import
    from repro.obs import trace

    if not autotune.telemetry_enabled():
        return
    leaves = jax.tree_util.tree_leaves(out)
    if any(isinstance(x, jax.core.Tracer) for x in leaves):
        return
    jax.block_until_ready(out)
    wall_s = time.perf_counter() - t0
    autotune.observe_pipeline(spec.name, wall_s, n_tiles)
    tracer = trace.get_tracer()
    end_us = tracer.now_us()
    tracer.complete(f"pipeline:{spec.name}", end_us - wall_s * 1e6,
                    wall_s * 1e6, tid=trace.TID_KERNEL, depth=depth,
                    n_tiles=n_tiles, context_bytes=spec.context_bytes(depth))


# ----------------------------------------------------------- the rotation


def coro_loop(
    n_tiles: int,
    depth: int,
    issue_fn: Callable[[Any, Any], None],
    consume_fn: Callable[[Any, Any, Any], Any],
    wait_fn: Callable[[Any, Any], None],
    carry_init: Any = 0,
    *,
    grid_step: Any = None,
):
    """Run the bare coroutine rotation over `n_tiles` with `depth` in flight.

    issue_fn(tile, slot)          - start the decoupled copies for `tile`
                                    into `slot` (aload/aset analogue)
    wait_fn(tile, slot)           - block until slot's copies landed (getfin)
    consume_fn(tile, slot, carry) - the coroutine body after resumption;
                                    returns updated carry

    `n_tiles`/`depth` are Python ints (grid is static); `tile`/`slot` are
    traced int32 inside the steady-state loop. `depth <= 0` is a no-op that
    returns `carry_init` (spec-level entry points reject it earlier).

    Two drive modes share the one rotation (warmup / wait / consume /
    recycle) so no kernel re-implements the schedule:

    * fori mode (`grid_step=None`, default): the whole pipeline runs inside
      one kernel invocation via `jax.lax.fori_loop` over all tiles
      (decode_attention, moe_gmm, ssd_scan).
    * grid mode (`grid_step=pl.program_id(...)`): the Pallas grid supplies
      the tile loop — each grid step executes exactly one pipeline step for
      tile `grid_step`, relying on VMEM scratch persisting across steps.
      Warmup runs once under `pl.when(grid_step == 0)`
      (coro_gather, coro_scatter_add, stream_copy).
    """
    depth = min(depth, n_tiles)
    if depth <= 0:
        return carry_init

    def warmup():
        # launch the initial coroutine batch (paper's Init Block)
        for t in range(depth):
            issue_fn(t, t)

    def step(t, carry):
        slot = jax.lax.rem(t, depth)
        # resume the coroutine whose data has arrived (bafin: the schedule is
        # compile-time so the "jump" costs nothing)
        wait_fn(t, slot)
        carry = consume_fn(t, slot, carry)

        # recycle the slot: launch the next iteration (paper's Return Block)
        @pl.when(t + depth < n_tiles)
        def _():
            issue_fn(t + depth, slot)

        return carry

    if grid_step is None:
        warmup()
        return jax.lax.fori_loop(0, n_tiles, step, carry_init)

    @pl.when(grid_step == 0)
    def _():
        warmup()

    return step(grid_step, carry_init)


# --------------------------------------------------------- stream plumbing


def _as_group(slices, group: int):
    if not isinstance(slices, (list, tuple)):
        slices = [slices]
    assert len(slices) == group, (len(slices), group)
    return slices


def _chunk(buf, slot, j: int, tile: Tuple[int, ...], group: int):
    rows = tile[0] // group
    return buf.at[slot, pl.ds(j * rows, rows)]


def _start_loads(stream: LoadStream, buf, sem, ctx, t, slot):
    srcs = _as_group(stream.src(ctx, t), stream.group)
    if stream.group == 1:
        pltpu.make_async_copy(srcs[0], buf.at[slot], sem.at[slot]).start()
        return
    for j, src in enumerate(srcs):
        pltpu.make_async_copy(src, _chunk(buf, slot, j, stream.tile,
                                          stream.group), sem.at[slot]).start()


def _wait_group(stream, buf, sem, slot):
    """Wait out a slot's outstanding copies (self-copy shaped waits): the
    arrival wait for a LoadStream, the drain for a StoreStream."""
    if stream.group == 1:
        pltpu.make_async_copy(buf.at[slot], buf.at[slot], sem.at[slot]).wait()
        return
    for j in range(stream.group):
        c = _chunk(buf, slot, j, stream.tile, stream.group)
        pltpu.make_async_copy(c, c, sem.at[slot]).wait()


def _start_stores(stream: StoreStream, buf, sem, ctx, t, slot):
    dsts = _as_group(stream.dst(ctx, t), stream.group)
    if stream.group == 1:
        pltpu.make_async_copy(buf.at[slot], dsts[0], sem.at[slot]).start()
        return
    for j, dst in enumerate(dsts):
        pltpu.make_async_copy(_chunk(buf, slot, j, stream.tile, stream.group),
                              dst, sem.at[slot]).start()


# ------------------------------------------------------- in-kernel engine


def coro_pipeline(
    spec: CoroSpec,
    ctx: CoroRefs,
    load_bufs: Sequence,
    store_bufs: Sequence,
    load_sem,
    store_sem,
    *,
    n_tiles: int,
    depth: int,
    body: Callable,
    prologue: Optional[Callable] = None,
    epilogue: Optional[Callable] = None,
    carry_init: Any = 0,
    grid_step: Any = None,
):
    """Drive a `CoroSpec` inside a Pallas kernel.

    body(ctx, tile, slot, carry) -> carry  - the coroutine body; reads load
        slots (`ctx.<stream>[slot]`), writes store slots, updates vars.
    prologue(ctx) -> carry_init            - fori mode only: per-invocation
        reset (accumulators, recurrent state) before warmup.
    epilogue(ctx, carry)                   - fori mode only: after the final
        store drain (normalize, write residual outputs).

    Store semantics (the RMW pipeline shared by coro_scatter_add and
    stream_copy): a slot's previous write-back is drained before the body
    may rewrite the slot (`tile >= depth`), new write-backs start right
    after the body, and every slot is drained once more when the rotation
    retires — under `pl.when(grid_step == n_tiles - 1)` in grid mode.
    """
    if depth is None or depth <= 0:
        raise ValueError(f"depth must be a positive int, got {depth}")
    depth = min(depth, n_tiles)
    if grid_step is not None and (prologue or epilogue):
        raise ValueError("prologue/epilogue require fori mode (grid_step=None)")

    def issue(t, slot):
        for s, buf in zip(spec.loads, load_bufs):
            _start_loads(s, buf, load_sem, ctx, t, slot)

    def wait(t, slot):
        for s, buf in zip(spec.loads, load_bufs):
            _wait_group(s, buf, load_sem, slot)

    def consume(t, slot, carry):
        if spec.stores:
            # drain the slot's previous write-back before the body rewrites it
            @pl.when(t >= depth)
            def _():
                for s, buf in zip(spec.stores, store_bufs):
                    _wait_group(s, buf, store_sem, slot)

        carry = body(ctx, t, slot, carry)

        for s, buf in zip(spec.stores, store_bufs):
            _start_stores(s, buf, store_sem, ctx, t, slot)
        return carry

    if prologue is not None:
        carry_init = prologue(ctx)

    carry = coro_loop(n_tiles, depth, issue, consume, wait, carry_init,
                      grid_step=grid_step)

    if spec.stores:
        # final drain: every slot has exactly one outstanding store at the
        # end (earlier ones were drained before their buffer was rewritten)
        def drain_all():
            for slot in range(min(depth, n_tiles)):
                for s, buf in zip(spec.stores, store_bufs):
                    _wait_group(s, buf, store_sem, slot)

        if grid_step is None:
            drain_all()
        else:
            @pl.when(grid_step == n_tiles - 1)
            def _():
                drain_all()

    if epilogue is not None:
        epilogue(ctx, carry)
    return carry


# ---------------------------------------------------- entry-point builder


def coro_call(
    spec: CoroSpec,
    *operands,
    n_tiles: int,
    depth: Optional[int],
    body: Callable,
    arg_names: Sequence[str],
    grid: Tuple[int, ...],
    in_specs,
    out_specs,
    out_shape,
    drive_axis: Optional[int] = None,
    prologue: Optional[Callable] = None,
    epilogue: Optional[Callable] = None,
    carry_init: Any = 0,
    num_scalar_prefetch: int = 0,
    input_output_aliases=None,
    interpret: bool = False,
):
    """Build and run the Pallas call for a `CoroSpec` kernel.

    `arg_names` names the kernel's operand refs in Pallas order (scalar-
    prefetch args, then inputs, then outputs); spec callbacks see them as
    `ctx.<name>`. `drive_axis` selects grid mode (that grid axis supplies
    the tile loop) vs fori mode (None: the pipeline runs inside each kernel
    invocation).

    With `depth=None` the pipeline depth is solved by
    `core.autotune.choose_depth` from the spec's tile profile, the VMEM cap
    coming from the classified context bytes (`spec.all_vars()`); the
    result is clamped to `n_tiles` and recorded under `spec.name` for
    `autotune.last_choice`.
    """
    from repro.core import autotune  # local: autotune imports context only

    if depth is None:
        depth = autotune.choose_depth(spec.profile(), kernel=spec.name,
                                      vars=spec.all_vars())
        depth = min(int(depth), n_tiles)
        # re-record post-clamp so last_choice reports the depth actually run
        autotune.record_choice(spec.name, depth)
    elif depth <= 0:
        raise ValueError(f"depth must be >= 1, got {depth}")
    depth = min(int(depth), n_tiles)

    n_outs = len(out_shape) if isinstance(out_shape, (list, tuple)) else 1
    n_named = num_scalar_prefetch + len(in_specs) + n_outs
    if len(arg_names) != n_named:
        raise ValueError(
            f"arg_names has {len(arg_names)} names for {n_named} operand refs")
    if "pids" in arg_names:
        raise ValueError("'pids' is reserved for the program-id tuple")
    spec_names = {s.name for s in (*spec.loads, *spec.stores)} \
        | {v.name for v in spec.vars} | {"pids"}
    clash = spec_names & set(arg_names)
    if clash:
        raise ValueError(
            f"arg_names collide with spec stream/var names: {sorted(clash)} "
            "(the stream buffer would shadow the operand ref in ctx)")

    loads, stores = spec.loads, spec.stores
    shaped_vars = spec.materialized_vars()

    def attempt(run_depth: int):
        """One guarded attempt: re-derive scratch shapes for `run_depth`
        and build + launch the pallas_call (the guard's backoff ladder
        re-enters here with halved depths — DESIGN.md §2.7)."""
        scratch = spec.scratch_shapes(run_depth)

        def kernel(*refs):
            named = dict(zip(arg_names, refs[:n_named]))
            rest = list(refs[n_named:])
            load_bufs = tuple(rest[:len(loads)])
            del rest[:len(loads)]
            store_bufs = tuple(rest[:len(stores)])
            del rest[:len(stores)]
            load_sem = rest.pop(0) if loads else None
            store_sem = rest.pop(0) if stores else None
            for v in shaped_vars:
                named[v.name] = rest.pop(0)
            assert not rest, "scratch ref count mismatch"
            for s, buf in zip((*loads, *stores), (*load_bufs, *store_bufs)):
                named[s.name] = buf
            # program ids, evaluated once at kernel entry (they cannot be
            # read from inside the fori-mode loop body): ctx.pids[axis]
            named["pids"] = tuple(pl.program_id(a) for a in range(len(grid)))
            ctx = CoroRefs(named)
            grid_step = (pl.program_id(drive_axis)
                         if drive_axis is not None else None)
            coro_pipeline(spec, ctx, load_bufs, store_bufs, load_sem,
                          store_sem, n_tiles=n_tiles, depth=run_depth,
                          body=body, prologue=prologue, epilogue=epilogue,
                          carry_init=carry_init, grid_step=grid_step)

        kwargs = {}
        if input_output_aliases is not None:
            kwargs["input_output_aliases"] = input_output_aliases
        if num_scalar_prefetch:
            grid_spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=num_scalar_prefetch,
                grid=grid,
                in_specs=in_specs,
                out_specs=out_specs,
                scratch_shapes=scratch,
            )
            call = pl.pallas_call(kernel, grid_spec=grid_spec,
                                  out_shape=out_shape, interpret=interpret,
                                  **kwargs)
        else:
            call = pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                                  out_specs=out_specs, out_shape=out_shape,
                                  scratch_shapes=scratch, interpret=interpret,
                                  **kwargs)
        return call(*operands)

    from repro.core import guard  # local: guard imports obs/kernels lazily

    res = guard.guarded_call(spec, operands, attempt,
                             depth=depth, n_tiles=n_tiles)
    if res.fallback:
        # the jnp twin answered: no pipeline ran, so nothing to observe,
        # and last_choice keeps the depth the solver proposed
        return res.out
    if res.depth != depth:
        # backoff landed on a lower depth: report the depth actually run
        autotune.record_choice(spec.name, res.depth)
    _observe_pipeline(spec, res.t0, res.out, n_tiles, res.depth)
    return res.out

"""Context minimization (CoroAMU §III-B) as a compile-time classifier.

The paper classifies each loop variable by how it is updated across
suspension points:

  private    - updated from its own iteration only; must live in the
               per-coroutine context (here: per-slot VMEM scratch x depth)
  shared     - read-only, or commutative updates (order-independent
               accumulation); lives once, outside any slot
  sequential - order-dependent updates; serialized into the loop carry
               (executed at coroutine launch/retire, never concurrent)

On TPU the "context" is the VMEM working set of the pipeline: private
variables multiply by `depth`, shared ones do not — so this classification
directly sizes the kernel scratch and bounds the reachable pipeline depth.

`core.coro` consumes these specs declaratively: a kernel's `CoroSpec` lists
its context as `VarSpec`s, and the builder derives each variable's scratch
shape from `classify()` — `(depth, *shape)` for private, `shape` (one copy)
for shared/sequential. A `VarSpec` with ``shape=None`` is accounting-only:
it is counted against the VMEM budget (an operand block or loop-carry
resident) but gets no scratch allocation of its own.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Dict, Iterable, Optional, Tuple

import numpy as np

# Depth values returned by `max_depth` are clamped here so an "unbounded"
# answer (no per-slot bytes at all) can never flow into a scratch-shape
# allocation or an unrolled warmup loop. Mirrors `schedule.REQUEST_SLOTS` —
# the paper's "capped only by SPM request slots" bound.
MAX_DEPTH = 64


class VarClass(enum.Enum):
    PRIVATE = "private"
    SHARED = "shared"
    SEQUENTIAL = "sequential"


@dataclasses.dataclass(frozen=True)
class VarSpec:
    """A value live across a suspension point."""

    name: str
    nbytes: int
    read_only: bool = False
    # update depends on the variable's previous value?
    carries_dependence: bool = False
    # if it does: is the combining op commutative+associative (add/min/max)?
    commutative: bool = False
    # programmer hint overriding the analysis (paper: pragma shared_var)
    hint: Optional[VarClass] = None
    # Materialization for the declarative builder (core.coro): when `shape`
    # is given the builder allocates VMEM scratch for this variable; when
    # None the bytes are budget-accounting only.
    shape: Optional[Tuple[int, ...]] = None
    dtype: Any = None


def var(name: str, shape: Tuple[int, ...], dtype, **kwargs) -> VarSpec:
    """A materialized `VarSpec`: nbytes derived from `shape` x `dtype`."""
    shape = tuple(int(s) for s in shape)
    nbytes = int(math.prod(shape)) * int(np.dtype(dtype).itemsize)
    return VarSpec(name=name, nbytes=nbytes, shape=shape, dtype=dtype, **kwargs)


def classify(v: VarSpec) -> VarClass:
    """The paper's three-way classification (§III-B)."""
    if v.hint is not None:
        return v.hint
    if v.read_only:
        return VarClass.SHARED
    if not v.carries_dependence:
        return VarClass.PRIVATE
    if v.commutative:
        return VarClass.SHARED  # order-free reduction: share one accumulator
    return VarClass.SEQUENTIAL


def classify_all(vs: Iterable[VarSpec]) -> Dict[str, VarClass]:
    return {v.name: classify(v) for v in vs}


def context_bytes(vs: Iterable[VarSpec], depth: int,
                  *, baseline: bool = False) -> int:
    """VMEM bytes of the pipeline context at a given depth.

    baseline=True models a conventional coroutine frame (everything private,
    as C++20 codegen would allocate) — the paper's Fig. 15 comparison point.
    """
    total = 0
    for v in vs:
        cls = VarClass.PRIVATE if baseline else classify(v)
        total += v.nbytes * (depth if cls is VarClass.PRIVATE else 1)
    return total


def max_depth(vs: Iterable[VarSpec], vmem_budget: int,
              *, baseline: bool = False, cap: int = MAX_DEPTH) -> int:
    """Largest pipeline depth whose context fits the VMEM budget.

    Clamped to `cap` (default `MAX_DEPTH`, the request-slot bound) so that a
    context with no per-slot bytes yields a finite, allocatable depth rather
    than a sentinel.
    """
    vs = list(vs)
    shared = sum(v.nbytes for v in vs
                 if not baseline and classify(v) is not VarClass.PRIVATE)
    per_slot = sum(v.nbytes for v in vs
                   if baseline or classify(v) is VarClass.PRIVATE)
    if per_slot == 0:
        return cap if shared <= vmem_budget else 0
    return min(max((vmem_budget - shared) // per_slot, 0), cap)

"""Context minimization (CoroAMU §III-B) as a compile-time classifier.

The paper classifies each loop variable by how it is updated across
suspension points:

  private    - updated from its own iteration only; must live in the
               per-coroutine context (here: per-slot VMEM scratch x depth)
  shared     - read-only, or commutative updates (order-independent
               accumulation); lives once, outside any slot
  sequential - order-dependent updates; serialized into the loop carry
               (executed at coroutine launch/retire, never concurrent)

On TPU the "context" is the VMEM working set of the pipeline: private
variables multiply by `depth`, shared ones do not — so this classification
directly sizes the kernel scratch and bounds the reachable pipeline depth.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Iterable, List, Optional, Tuple


class VarClass(enum.Enum):
    PRIVATE = "private"
    SHARED = "shared"
    SEQUENTIAL = "sequential"


@dataclasses.dataclass(frozen=True)
class VarSpec:
    """A value live across a suspension point."""

    name: str
    nbytes: int
    read_only: bool = False
    # update depends on the variable's previous value?
    carries_dependence: bool = False
    # if it does: is the combining op commutative+associative (add/min/max)?
    commutative: bool = False
    # programmer hint overriding the analysis (paper: pragma shared_var)
    hint: Optional[VarClass] = None


def classify(v: VarSpec) -> VarClass:
    """The paper's three-way classification (§III-B)."""
    if v.hint is not None:
        return v.hint
    if v.read_only:
        return VarClass.SHARED
    if not v.carries_dependence:
        return VarClass.PRIVATE
    if v.commutative:
        return VarClass.SHARED  # order-free reduction: share one accumulator
    return VarClass.SEQUENTIAL


def classify_all(vs: Iterable[VarSpec]) -> Dict[str, VarClass]:
    return {v.name: classify(v) for v in vs}


def context_bytes(vs: Iterable[VarSpec], depth: int,
                  *, baseline: bool = False) -> int:
    """VMEM bytes of the pipeline context at a given depth.

    baseline=True models a conventional coroutine frame (everything private,
    as C++20 codegen would allocate) — the paper's Fig. 15 comparison point.
    """
    total = 0
    for v in vs:
        cls = VarClass.PRIVATE if baseline else classify(v)
        total += v.nbytes * (depth if cls is VarClass.PRIVATE else 1)
    return total


def max_depth(vs: Iterable[VarSpec], vmem_budget: int,
              *, baseline: bool = False) -> int:
    """Largest pipeline depth whose context fits the VMEM budget."""
    vs = list(vs)
    shared = sum(v.nbytes for v in vs
                 if not baseline and classify(v) is not VarClass.PRIVATE)
    per_slot = sum(v.nbytes for v in vs
                   if baseline or classify(v) is VarClass.PRIVATE)
    if per_slot == 0:
        return 2 ** 30 if shared <= vmem_budget else 0
    return max((vmem_budget - shared) // per_slot, 0)

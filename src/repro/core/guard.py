"""Guarded execution for the coroutine kernel substrate (DESIGN.md §2.7).

ISSUE-9 made the serving engine crash-proof; this module gives the layer
underneath it — every `coro_call` pipeline — a defined completion/failure
contract: a guarded call either returns a correct result or degrades
through a declared ladder, never an unhandled exception and never silent
wrong numbers. The pieces:

* **Error taxonomy** — `SubstrateError` (kernel name, machine profile,
  depth, tile shape) with four concrete classes: `KernelCompileError`
  (Mosaic/lowering failures), `KernelResourceError` (RESOURCE_EXHAUSTED /
  VMEM overcommit), `KernelNumericsError` (non-finite outputs), and
  `KernelParityError` (sentinel mismatch vs the jnp twin).
* **Depth-backoff ladder** — a failed attempt at depth d is retried at
  `max(1, d // 2)`, re-deriving scratch shapes each step (the caller's
  `attempt(d)` closure rebuilds the pallas_call), until depth 1 fails too.
* **Twin fallback** — on ladder exhaustion the kernel family's registered
  jnp twin (`repro.kernels.fallback_twin`) computes the answer instead.
* **Circuit breaker** — per (machine, kernel): closed → open after
  `BREAKER_THRESHOLD` consecutive failures → half-open probe after
  `BREAKER_COOLDOWN_CALLS` guarded calls → closed on probe success. While
  open, calls route straight to the twin without attempting the kernel.
* **Config quarantine** — every failed (machine, kernel, depth) is pushed
  into `core.autotune`'s quarantine set so `choose_depth` never re-proposes
  a depth that just failed.
* **Parity sentinel** — opt-in (`REPRO_PARITY`: ``off`` | ``sampled`` |
  ``full``): a deterministic 1-in-N sample of guarded calls is re-run
  through the twin and compared within tolerance; a mismatch returns the
  twin's output and trips the same quarantine/breaker path. Always on,
  regardless of mode: a cheap NaN/Inf scan of every concrete output.
* **Strict mode** — `set_strict(True)` (serve.py/kernel_bench ``--strict``)
  disables every degradation: the first failure raises its typed error.

Every backoff, fallback, breaker transition, and parity mismatch emits an
`obs` trace instant plus counters (`substrate.backoffs`,
`substrate.fallbacks`, `substrate.parity_mismatches`, a breaker-state
gauge). `stats()` reports plain-int totals that survive
``REPRO_TELEMETRY=0``.

Fault injection: `set_injector` installs a `serve.faults`-style injector
whose ``kernel_compile`` / ``kernel_oom`` / ``kernel_nan`` streams fire
inside `guarded_call`; `check_injected` raises the same typed errors at
engine call sites (useful where pool donation forbids failing mid-call).
This module must not import `serve.faults` at module scope (serve imports
kernels imports core.coro imports this) — the null injector is local.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "BREAKER_COOLDOWN_CALLS",
    "BREAKER_THRESHOLD",
    "GuardResult",
    "KernelCompileError",
    "KernelNumericsError",
    "KernelParityError",
    "KernelResourceError",
    "SubstrateError",
    "breaker_state",
    "check_injected",
    "guarded_call",
    "last_ladder",
    "parity_mode",
    "reset",
    "scan_output",
    "set_injector",
    "set_parity",
    "set_strict",
    "stats",
    "strict_mode",
]

PARITY_ENV = "REPRO_PARITY"            # off | sampled | full
PARITY_EVERY_ENV = "REPRO_PARITY_EVERY"
DEFAULT_PARITY_EVERY = 4               # sampled mode checks call 1, N+1, ...

BREAKER_THRESHOLD = 3                  # consecutive failures -> open
BREAKER_COOLDOWN_CALLS = 8             # open calls before a half-open probe

# substrings that mark a failure as resource pressure rather than a
# compile/lowering bug (jax surfaces TPU OOM as RESOURCE_EXHAUSTED; Mosaic
# VMEM overcommit mentions vmem/scoped memory)
_RESOURCE_MARKERS = ("resource_exhausted", "resource exhausted",
                     "out of memory", "vmem", "scoped vmem", "smem")


# ---------------------------------------------------------------- taxonomy


class SubstrateError(RuntimeError):
    """A kernel-substrate failure with its launch context attached.

    Subclass of RuntimeError so seed-era supervisors whose retriable set is
    ``(RuntimeError, OSError)`` (`runtime.fault_tolerance`) treat substrate
    faults as retriable without being taught the new taxonomy.
    """

    def __init__(self, message: str, *, kernel: str = "?",
                 machine: Optional[str] = None, depth: Optional[int] = None,
                 tile: Optional[Tuple[int, ...]] = None):
        if machine is None:
            machine = _machine_name()
        super().__init__(
            f"{message} [kernel={kernel} machine={machine} depth={depth} "
            f"tile={tile}]")
        self.kernel = kernel
        self.machine = machine
        self.depth = depth
        self.tile = tile


class KernelCompileError(SubstrateError):
    """Mosaic/lowering/launch failure (or an injected stand-in)."""


class KernelResourceError(SubstrateError):
    """RESOURCE_EXHAUSTED / VMEM overcommit at the attempted depth."""


class KernelNumericsError(SubstrateError):
    """Non-finite values in a kernel's output (the always-on scan)."""


class KernelParityError(SubstrateError):
    """Sentinel mismatch: kernel output diverged from the jnp twin."""


def _machine_name() -> str:
    try:
        from repro.core.machine import get_machine
        return get_machine().name
    except Exception:  # pragma: no cover - machine layer must not gate errors
        return "?"


# ------------------------------------------------------------ module state


class _NullInjector:
    """Default injector: never fires. serve.faults.NULL_INJECTOR has the
    same surface, but importing it here would close an import cycle."""

    __slots__ = ()

    def fire(self, site: str, **ctx: Any) -> bool:
        return False


_NULL_INJECTOR = _NullInjector()

_COUNT_KEYS = ("guarded_calls", "clean_calls", "backoffs", "fallbacks",
               "breaker_trips", "parity_checks", "parity_mismatches",
               "numerics_faults", "injected_faults")

_lock = threading.RLock()
_strict: bool = False
_parity_mode: str = "off"
_parity_every: int = DEFAULT_PARITY_EVERY
_injector: Any = _NULL_INJECTOR
_counts: Dict[str, int] = {}
_breakers: Dict[Tuple[str, str], "_Breaker"] = {}
_parity_counter: Dict[Tuple[str, str], int] = {}
_last_ladder: Dict[Tuple[str, str], List[int]] = {}


@dataclasses.dataclass
class _Breaker:
    state: str = "closed"          # closed | open | half_open
    failures: int = 0              # consecutive, while closed
    open_calls: int = 0            # guarded calls seen while open


def _key(kernel: str) -> Tuple[str, str]:
    return (_machine_name(), kernel)


def _env_parity() -> Tuple[str, int]:
    mode = os.environ.get(PARITY_ENV, "off").strip().lower()
    if mode not in ("off", "sampled", "full"):
        mode = "off"
    try:
        every = max(1, int(os.environ.get(PARITY_EVERY_ENV,
                                          DEFAULT_PARITY_EVERY)))
    except ValueError:
        every = DEFAULT_PARITY_EVERY
    return mode, every


def reset() -> None:
    """Re-resolve from the environment with empty state (test isolation:
    the autouse conftest fixture calls this between tests)."""
    global _strict, _parity_mode, _parity_every, _injector
    with _lock:
        _strict = False
        _parity_mode, _parity_every = _env_parity()
        _injector = _NULL_INJECTOR
        _counts.clear()
        _counts.update({k: 0 for k in _COUNT_KEYS})
        _breakers.clear()
        _parity_counter.clear()
        _last_ladder.clear()


reset()


def set_strict(on: bool) -> None:
    """Disable degradation: failures raise their typed `SubstrateError`
    instead of walking the ladder / falling back (``--strict`` CI lanes)."""
    global _strict
    _strict = bool(on)


def strict_mode() -> bool:
    return _strict


def set_parity(mode: str, every: Optional[int] = None) -> None:
    """Set the sentinel mode: ``off`` | ``sampled`` (1-in-`every`) |
    ``full`` (every concrete call)."""
    global _parity_mode, _parity_every
    if mode not in ("off", "sampled", "full"):
        raise ValueError(f"parity mode must be off|sampled|full, got {mode!r}")
    _parity_mode = mode
    if every is not None:
        _parity_every = max(1, int(every))


def parity_mode() -> str:
    return _parity_mode


def set_injector(injector: Optional[Any]) -> None:
    """Install a `serve.faults.FaultInjector` (or None to clear) whose
    kernel-site streams fire inside every guarded call."""
    global _injector
    _injector = injector if injector is not None else _NULL_INJECTOR


def breaker_state(kernel: str) -> str:
    with _lock:
        br = _breakers.get(_key(kernel))
        return br.state if br is not None else "closed"


def last_ladder(kernel: str) -> List[int]:
    """Depths attempted by the most recent guarded call for `kernel` under
    the active machine (monotonically halving on failure)."""
    with _lock:
        return list(_last_ladder.get(_key(kernel), ()))


def stats() -> Dict[str, Any]:
    """Plain-int substrate totals (process-wide; survives
    ``REPRO_TELEMETRY=0``). `telemetry_summary()` and the default metrics
    registry fold this in as the ``substrate`` section/view."""
    with _lock:
        out: Dict[str, Any] = {k: _counts.get(k, 0) for k in _COUNT_KEYS}
        out["strict"] = _strict
        out["parity"] = _parity_mode
        out["breakers"] = {k[1]: br.state for k, br in sorted(_breakers.items())
                           if br.state != "closed"}
    return out


def _count(name: str, n: int = 1) -> None:
    with _lock:
        _counts[name] = _counts.get(name, 0) + n
    _registry_counter(f"substrate.{name}").inc(n)


def _registry_counter(name: str):
    from repro.obs import metrics
    return metrics.default_registry().counter(name)


def _tracer():
    from repro.obs import trace
    return trace.get_tracer()


# ------------------------------------------------------------- the breaker


_BREAKER_GAUGE = {"closed": 0.0, "half_open": 0.5, "open": 1.0}


def _transition(kernel: str, br: _Breaker, state: str) -> None:
    if br.state == state:
        return
    br.state = state
    if state == "open":
        br.open_calls = 0
        _count("breaker_trips")
    from repro.obs import metrics
    metrics.default_registry().gauge(
        f"substrate.breaker.{kernel}").set(_BREAKER_GAUGE[state])
    from repro.obs import trace
    _tracer().instant(f"breaker_{state}", tid=trace.TID_KERNEL, kernel=kernel)


def _note_failure(kernel: str, br: _Breaker) -> None:
    br.failures += 1
    if br.state == "half_open":
        _transition(kernel, br, "open")       # probe failed: re-open
    elif br.state == "closed" and br.failures >= BREAKER_THRESHOLD:
        _transition(kernel, br, "open")


def _note_success(kernel: str, br: _Breaker) -> None:
    br.failures = 0
    if br.state != "closed":
        _transition(kernel, br, "closed")


# -------------------------------------------------------- output policing


def _is_concrete(x: Any) -> bool:
    return not any(isinstance(leaf, jax.core.Tracer)
                   for leaf in jax.tree_util.tree_leaves(x))


def scan_output(kernel: str, out: Any, *,
                depth: Optional[int] = None) -> Optional[KernelNumericsError]:
    """The always-on NaN/Inf scan: returns a `KernelNumericsError` if any
    concrete floating leaf of `out` is non-finite, else None. Skipped under
    jit tracing (no concrete values to police)."""
    if not _is_concrete(out):
        return None
    for leaf in jax.tree_util.tree_leaves(out):
        if not hasattr(leaf, "dtype"):
            continue
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        if not bool(jnp.isfinite(leaf).all()):
            _count("numerics_faults")
            from repro.obs import trace
            _tracer().instant("substrate_nonfinite", tid=trace.TID_KERNEL,
                              kernel=kernel, depth=depth)
            return KernelNumericsError(
                "non-finite values in kernel output", kernel=kernel,
                depth=depth)
    return None


def _tolerance(leaves: Sequence[Any]) -> Tuple[float, float]:
    for leaf in leaves:
        if hasattr(leaf, "dtype") and leaf.dtype in (jnp.bfloat16, jnp.float16):
            return 3e-2, 3e-2
    return 2e-3, 2e-3


def _parity_matches(out: Any, ref: Any) -> bool:
    a = jax.tree_util.tree_leaves(out)
    b = jax.tree_util.tree_leaves(ref)
    if len(a) != len(b):
        return False
    rtol, atol = _tolerance(a)
    for x, y in zip(a, b):
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        if x.shape != y.shape:
            return False
        if jnp.issubdtype(x.dtype, jnp.floating) \
                or jnp.issubdtype(y.dtype, jnp.floating):
            ok = jnp.allclose(x.astype(jnp.float32), y.astype(jnp.float32),
                              rtol=rtol, atol=atol)
        else:
            ok = (x == y).all()
        if not bool(ok):
            return False
    return True


# --------------------------------------------------------- fault injection


def check_injected(kernel: str, injector: Optional[Any] = None,
                   **ctx: Any) -> None:
    """Fire the kernel-site fault streams and raise the matching typed
    error. Engine call sites use this *before* a donating jit call — pool
    buffers must not be consumed by an attempt that is about to fail."""
    inj = injector if injector is not None else _injector
    if inj.fire("kernel_compile", kernel=kernel, **ctx):
        _count("injected_faults")
        raise KernelCompileError("injected kernel compile failure",
                                 kernel=kernel)
    if inj.fire("kernel_oom", kernel=kernel, **ctx):
        _count("injected_faults")
        raise KernelResourceError("injected RESOURCE_EXHAUSTED",
                                  kernel=kernel)
    if inj.fire("kernel_nan", kernel=kernel, **ctx):
        _count("injected_faults")
        raise KernelNumericsError("injected non-finite kernel output",
                                  kernel=kernel)


def _inject_pre(kernel: str, depth: int) -> None:
    if _injector.fire("kernel_compile", kernel=kernel, depth=depth):
        _count("injected_faults")
        raise KernelCompileError("injected kernel compile failure",
                                 kernel=kernel, depth=depth)
    if _injector.fire("kernel_oom", kernel=kernel, depth=depth):
        _count("injected_faults")
        raise KernelResourceError("injected RESOURCE_EXHAUSTED",
                                  kernel=kernel, depth=depth)


def _inject_poison(kernel: str, out: Any) -> Any:
    """kernel_nan stream: poison the first floating leaf of a successful
    attempt's output so the always-on scan must catch it."""
    if not _injector.fire("kernel_nan", kernel=kernel):
        return out
    _count("injected_faults")
    leaves, treedef = jax.tree_util.tree_flatten(out)
    for i, leaf in enumerate(leaves):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            leaves[i] = jnp.full_like(leaf, jnp.nan)
            return jax.tree_util.tree_unflatten(treedef, leaves)
    return out


# ------------------------------------------------------------ guarded_call


@dataclasses.dataclass
class GuardResult:
    """What a guarded call produced and how it got there."""

    out: Any
    depth: int
    path: str           # clean | backoff | twin | breaker
    t0: float = 0.0     # perf_counter at the start of the successful attempt

    @property
    def fallback(self) -> bool:
        return self.path in ("twin", "breaker")


def _resolve_twin(kernel: str) -> Optional[Callable[..., Any]]:
    try:
        from repro import kernels as kernels_pkg
        return kernels_pkg.fallback_twin(kernel)
    except Exception:  # pragma: no cover - registry import must not gate
        return None


def _classify(exc: Exception, kernel: str, depth: int,
              tile: Optional[Tuple[int, ...]]) -> SubstrateError:
    if isinstance(exc, SubstrateError):
        return exc
    msg = f"{type(exc).__name__}: {exc}"
    cls = KernelCompileError
    low = msg.lower()
    if any(marker in low for marker in _RESOURCE_MARKERS):
        cls = KernelResourceError
    err = cls(msg, kernel=kernel, depth=depth, tile=tile)
    err.__cause__ = exc
    return err


def _spec_tile(spec: Any) -> Optional[Tuple[int, ...]]:
    streams = (*getattr(spec, "loads", ()), *getattr(spec, "stores", ()))
    return tuple(streams[0].tile) if streams else None


def _run_twin(spec: Any, operands: Sequence[Any],
              twin: Callable[..., Any], depth: int, path: str,
              cause: Optional[SubstrateError]) -> GuardResult:
    _count("fallbacks")
    from repro.obs import trace
    _tracer().instant("substrate_fallback", tid=trace.TID_KERNEL,
                      kernel=spec.name, path=path,
                      error=type(cause).__name__ if cause else None)
    t0 = time.perf_counter()
    try:
        out = twin(spec, *operands)
    except Exception as twin_exc:
        if cause is not None:
            raise cause from twin_exc
        raise
    return GuardResult(out=out, depth=depth, path=path, t0=t0)


def _maybe_parity(spec: Any, operands: Sequence[Any], out: Any, depth: int,
                  twin: Optional[Callable[..., Any]]) -> Tuple[Any, bool]:
    """Returns (output, mismatched). On mismatch the twin's output is
    substituted (non-strict) or `KernelParityError` raised (strict)."""
    if twin is None or _parity_mode == "off":
        return out, False
    if not _is_concrete(out) or not _is_concrete(operands):
        return out, False
    key = _key(spec.name)
    with _lock:
        n = _parity_counter.get(key, 0) + 1
        _parity_counter[key] = n
    if _parity_mode == "sampled" and (n - 1) % _parity_every:
        return out, False
    _count("parity_checks")
    try:
        ref = twin(spec, *operands)
    except Exception:
        return out, False           # the twin cannot police this call
    if _parity_matches(out, ref):
        return out, False
    _count("parity_mismatches")
    from repro.obs import trace
    _tracer().instant("parity_mismatch", tid=trace.TID_KERNEL,
                      kernel=spec.name, depth=depth)
    if _strict:
        raise KernelParityError("kernel output diverged from jnp twin",
                                kernel=spec.name, depth=depth,
                                tile=_spec_tile(spec))
    return ref, True


def guarded_call(spec: Any, operands: Sequence[Any],
                 attempt: Callable[[int], Any], *,
                 depth: int, n_tiles: int) -> GuardResult:
    """Run `attempt(depth)` under the substrate guard.

    `attempt` must rebuild the kernel for the depth it is given (scratch
    shapes re-derived each step — `coro_call` closes over its pallas_call
    builder). On failure the depth ladder halves toward 1; on exhaustion
    the registered jnp twin answers; parity/NaN policing and the breaker
    wrap every path. Raises only in strict mode, on KeyboardInterrupt /
    SystemExit, or when no twin is registered for `spec.name`.
    """
    kernel = spec.name
    key = _key(kernel)
    tile = _spec_tile(spec)
    twin = _resolve_twin(kernel)
    _count("guarded_calls")
    with _lock:
        br = _breakers.setdefault(key, _Breaker())

    # breaker routing (never in strict mode: strict means "surface it")
    if not _strict and br.state == "open":
        br.open_calls += 1
        if br.open_calls < BREAKER_COOLDOWN_CALLS:
            if twin is not None:
                return _run_twin(spec, operands, twin, depth, "breaker", None)
            # no twin to route to: attempt anyway
        else:
            _transition(kernel, br, "half_open")   # cooldown over: probe

    from repro.obs import trace
    tracer = _tracer()
    ladder: List[int] = []
    d = min(int(depth), n_tiles) if n_tiles > 0 else int(depth)
    err: Optional[SubstrateError] = None
    while True:
        ladder.append(d)
        t0 = time.perf_counter()
        try:
            _inject_pre(kernel, d)
            out = attempt(d)
            out = _inject_poison(kernel, out)
            nerr = scan_output(kernel, out, depth=d)
            if nerr is not None:
                raise nerr
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as exc:  # noqa: BLE001 - classified below
            err = _classify(exc, kernel, d, tile)
        else:
            err = None

        if err is None:
            out, mismatched = _maybe_parity(spec, operands, out, d, twin)
            with _lock:
                _last_ladder[key] = ladder
            if mismatched:
                # the twin's output was substituted: a correctness failure
                # feeds the breaker/quarantine exactly like a crash would
                _quarantine(kernel, d)
                _note_failure(kernel, br)
                _count("fallbacks")
                return GuardResult(out=out, depth=d, path="twin", t0=t0)
            _note_success(kernel, br)
            if len(ladder) == 1:
                _count("clean_calls")
                return GuardResult(out=out, depth=d, path="clean", t0=t0)
            return GuardResult(out=out, depth=d, path="backoff", t0=t0)

        # attempt at depth d failed
        _quarantine(kernel, d)
        _note_failure(kernel, br)
        if _strict:
            with _lock:
                _last_ladder[key] = ladder
            raise err
        if d <= 1:
            break
        nxt = max(1, d // 2)
        _count("backoffs")
        tracer.instant("substrate_backoff", tid=trace.TID_KERNEL,
                       kernel=kernel, from_depth=d, to_depth=nxt,
                       error=type(err).__name__)
        d = nxt

    with _lock:
        _last_ladder[key] = ladder
    if twin is None:
        raise err
    return _run_twin(spec, operands, twin, 1, "twin", err)


def _quarantine(kernel: str, depth: int) -> None:
    from repro.core import autotune
    autotune.quarantine_config(kernel, depth)

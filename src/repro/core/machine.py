"""The one machine description every layer reads (CoroAMU's latency dial).

The paper's central knob is latency: the coroutine schedule is re-solved as
far-memory latency dials from 200ns to 800ns (§III-D, §V), and the AMU line
of work argues the latency model must be a first-class *runtime* input, not
a compile-time constant scattered through the code. This module is that
input: a frozen `MachineModel` dataclass holding every hardware constant
the repo reasons with, a table of named profiles, and a process-wide
active-profile switch (`set_machine`/`get_machine`, seeded from the
`REPRO_MACHINE` env var).

Consumers (one definition, many readers):

  core.schedule   - solve_depth/adaptive_depth/achieved_bandwidth read
                    peak_flops / hbm_bw / hbm_latency_s / vmem_bytes /
                    request_slots from the active (or passed) model
  core.autotune   - choose_depth keys its feedback store by
                    (machine, kernel) so a profile switch never reuses
                    stale latency samples
  repro.roofline  - the compute/memory/collective terms read the same
                    peak_flops / hbm_bw / ici_bw the depth solver uses
  core.sim        - the calibrated NH-G model derives its clock and
                    far-memory bandwidth from the `nh-g` profile
                    (cross-checked in `core.sim.calibration_check`)
  kernels/*/ops   - interpret-mode defaults consult the active backend

Legacy constant names (`PEAK_FLOPS`, `HBM_BW`, `HBM_LATENCY_S`,
`VMEM_BYTES`, `ICI_BW`, `REQUEST_SLOTS`) resolve through module
`__getattr__` to the *active* profile, here and in `core.schedule` /
`repro.roofline` — thin aliases, not second definitions.

Profile selection::

  REPRO_MACHINE=v5e-far-800ns python -m pytest ...   # env var, at import
  set_machine("v5e-far-200ns")                       # process-wide, runtime
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Dict, Optional, Tuple, Union

__all__ = [
    "MachineModel",
    "MACHINES",
    "DEFAULT_MACHINE",
    "MACHINE_ENV",
    "get_machine",
    "set_machine",
    "machine_profile",
    "profile_names",
    "default_interpret",
]

MACHINE_ENV = "REPRO_MACHINE"


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Everything the schedule/roofline/sim layers know about one machine."""

    name: str
    peak_flops: float        # sustained FLOP/s (bf16 on TPU profiles)
    hbm_bw: float            # bytes/s to the far store (HBM on-chip)
    hbm_latency_s: float     # round-trip latency one decoupled DMA sees
    vmem_bytes: int          # scratchpad (VMEM / SPM) capacity
    ici_bw: float            # bytes/s per interconnect link (collectives)
    request_slots: int       # outstanding-DMA bound ("SPM request slots")
    clock_ghz: float         # core clock (cycles <-> seconds in core.sim)
    backend: str = "tpu"     # "tpu" | "interpret": kernel dispatch default

    def replace(self, **kw) -> "MachineModel":
        return dataclasses.replace(self, **kw)

    def summary(self) -> Dict[str, float]:
        return {
            "machine": self.name,
            "peak_tflops": self.peak_flops / 1e12,
            "hbm_gbps": self.hbm_bw / 1e9,
            "hbm_latency_ns": self.hbm_latency_s * 1e9,
            "vmem_mib": self.vmem_bytes / (1 << 20),
            "request_slots": self.request_slots,
        }


_V5E = MachineModel(
    name="v5e",
    peak_flops=197e12,            # bf16, per chip (datasheet)
    hbm_bw=819e9,
    hbm_latency_s=700e-9,         # HBM round-trip seen by a DMA
    vmem_bytes=128 * 1024 * 1024,
    ici_bw=50e9,                  # per link
    request_slots=64,             # paper's "capped only by SPM request slots"
    clock_ghz=0.94,
)

# The paper's latency dial (§V): the same chip in front of far memory that
# adds 200ns-800ns on top of local HBM at UNCHANGED bandwidth — the paper
# sweeps latency with bandwidth held fixed, which is exactly what isolates
# the schedule's latency tolerance (halving bandwidth would *lengthen* each
# tile's transfer and so *shrink* the depth needed to hide the dial). The
# AMU these profiles model provisions a larger request-slot arena —
# covering more latency takes more coroutines in flight (§III-D), and the
# SPM slot bound is a property of the memory unit, not the core.
_FAR_SLOTS = 256

MACHINES: Dict[str, MachineModel] = {
    "v5e": _V5E,
    "v5e-far-200ns": _V5E.replace(
        name="v5e-far-200ns",
        hbm_latency_s=_V5E.hbm_latency_s + 200e-9,
        request_slots=_FAR_SLOTS,
    ),
    "v5e-far-800ns": _V5E.replace(
        name="v5e-far-800ns",
        hbm_latency_s=_V5E.hbm_latency_s + 800e-9,
        request_slots=_FAR_SLOTS,
    ),
    # The container this repo develops in: Pallas interpret mode on one CPU
    # core. Compute dwarfs transfer, so solved depths collapse toward the
    # floor — picking this profile documents that interpret timings are not
    # TPU performance (benchmarks/kernel_bench.py docstring).
    "cpu-interpret": MachineModel(
        name="cpu-interpret",
        peak_flops=5e10,
        hbm_bw=20e9,
        hbm_latency_s=100e-9,
        vmem_bytes=128 * 1024 * 1024,
        ici_bw=0.0,
        request_slots=16,
        clock_ghz=3.0,
        backend="interpret",
    ),
    # The paper's FPGA-emulated NH-G RISC-V SoC (Table I): core.sim derives
    # its clock and far-memory bandwidth from here and cross-checks them
    # (sim.calibration_check). 16 B/cycle at 3 GHz = 48 GB/s far bandwidth.
    "nh-g": MachineModel(
        name="nh-g",
        peak_flops=7.5e9,          # 2.5 sustained IPC x 3 GHz
        hbm_bw=48e9,
        hbm_latency_s=700e-9,      # mid-dial; sim sweeps 100ns-1us anyway
        vmem_bytes=64 * 1024,      # SPM
        ici_bw=0.0,
        request_slots=64,          # AMU slots (Fig. 16: MLP peaks ~64)
        clock_ghz=3.0,
        backend="interpret",
    ),
}

DEFAULT_MACHINE = "v5e"

_lock = threading.Lock()


def machine_profile(name: str) -> MachineModel:
    try:
        return MACHINES[name]
    except KeyError:
        raise KeyError(
            f"unknown machine profile {name!r}; known: {sorted(MACHINES)}"
        ) from None


def profile_names() -> Tuple[str, ...]:
    return tuple(MACHINES)


def _initial() -> MachineModel:
    return machine_profile(os.environ.get(MACHINE_ENV, DEFAULT_MACHINE))


_active: MachineModel = _initial()


def get_machine() -> MachineModel:
    """The process-wide active machine model."""
    return _active


def set_machine(m: Union[str, MachineModel, None] = None) -> MachineModel:
    """Switch the active profile (by name, or an ad-hoc `MachineModel`).

    ``set_machine(None)`` re-resolves from `REPRO_MACHINE`/the default —
    what the test fixture uses to reset between tests. Returns the now-
    active model. `core.autotune` keys its feedback store by machine name,
    so switching never reuses another profile's latency samples.
    """
    global _active
    with _lock:
        if m is None:
            _active = _initial()
        elif isinstance(m, MachineModel):
            _active = m
        else:
            _active = machine_profile(m)
        return _active


def default_interpret() -> bool:
    """Kernel entry points' interpret default: the declared backend when the
    active profile pins one, else whatever jax is actually running on."""
    if get_machine().backend == "interpret":
        return True
    import jax  # local: keep machine importable without jax

    return jax.default_backend() != "tpu"


_ALIASES = {
    "PEAK_FLOPS": "peak_flops",
    "HBM_BW": "hbm_bw",
    "HBM_LATENCY_S": "hbm_latency_s",
    "VMEM_BYTES": "vmem_bytes",
    "ICI_BW": "ici_bw",
    "REQUEST_SLOTS": "request_slots",
}


def __getattr__(name: str):
    # Legacy constant names resolve against the ACTIVE profile (PEP 562) —
    # one definition here, thin aliases everywhere else.
    attr = _ALIASES.get(name)
    if attr is not None:
        return getattr(get_machine(), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Latency-aware depth autotuning for the coroutine kernels (CoroAMU §III-D).

This module is the glue between the depth solver (`core.schedule`) and the
kernel entry points (`kernels/*/ops.py`): every kernel family describes one
in-flight tile as a `TileProfile` (bytes DMA'd, flops after resumption, and
the VMEM its slot occupies), and `choose_depth` turns that profile into the
pipeline depth — the software analogue of the paper's Return-Block dynamic
scheduler picking how many coroutines to keep in flight.

Two paths:

* static solve — `choose_depth(profile)` with no recorded samples returns
  exactly `schedule.solve_depth(profile)` for the ACTIVE machine profile
  (`core.machine`): the smallest depth that hides the modelled latency,
  capped by the VMEM budget and the profile's request slots. Kernel entry
  points call this when invoked with ``depth=None``.
* run-time feedback — `record_transfer(kernel, seconds)` accumulates
  measured per-tile transfer latencies; once samples exist for a kernel,
  `choose_depth` re-solves from the observed tail latency via
  `schedule.adaptive_depth`, adapting the schedule to the latency actually
  seen instead of the data-sheet constant.

The feedback store is keyed by **(machine, kernel)**: switching the active
profile (`machine.set_machine`, `REPRO_MACHINE`) never reuses another
profile's latency samples — the paper's latency dial re-solves from scratch.

Always-on telemetry (ISSUE-6): `core.coro.coro_call` times every launched
pipeline and calls `observe_pipeline(kernel, wall_s, n_tiles)`; the serving
engines feed their decode rounds the same way. The first observation of a
(machine, kernel, n_tiles) triple is treated as compile warmup and dropped;
every later one lands in `record_transfer` as wall-clock / tiles — so ANY
workload tightens the schedule, not just the benchmark harness.
`telemetry_summary()` exposes per-kernel sample count, p50/p99 observed
per-tile latency, and the static-vs-adaptive depth each kernel last ran.

`last_choice(kernel)` exposes the most recent decision so benchmarks and
tests can report/assert the depth a ``depth=None`` call actually used.

API stability note: `TileProfile` is defined in `core.schedule` and
re-exported here. Kernel entry points built on `core.coro.coro_call`
derive their profile from the declarative `CoroSpec`
(``spec.profile()``) and pass ``vars=spec.all_vars()`` so the VMEM cap
comes from the classified context bytes; the ``profile_*`` helpers below
remain the standalone traffic/flops models used by benchmarks and the
modelled-latency figures.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from repro.core import context as ctx_mod
from repro.core.machine import MachineModel, get_machine
from repro.core.schedule import (
    TileProfile,
    adaptive_depth,
    solve_depth,
)
from repro.obs.metrics import percentile

__all__ = [
    "TileProfile",
    "choose_depth",
    "clear_quarantine",
    "clear_samples",
    "is_quarantined",
    "last_choice",
    "quarantine_config",
    "quarantined_depths",
    "last_profile",
    "observe_pipeline",
    "profile_decode",
    "profile_gmm",
    "profile_row_gather",
    "profile_scatter_add",
    "profile_span_gather",
    "profile_ssd",
    "profile_triad",
    "record_choice",
    "record_transfer",
    "set_telemetry",
    "telemetry_enabled",
    "telemetry_summary",
    "transfer_samples",
]

TELEMETRY_ENV = "REPRO_TELEMETRY"
# bound the always-on store: a serving process records forever
MAX_SAMPLES_PER_KERNEL = 512

_lock = threading.Lock()
# all three stores are keyed (machine_name, kernel): a profile switch never
# reuses stale samples or reports another machine's decisions
_transfer_samples: Dict[Tuple[str, str], List[float]] = {}
_last_choice: Dict[Tuple[str, str], int] = {}
_last_mode: Dict[Tuple[str, str], str] = {}       # "static" | "adaptive"
_last_profile: Dict[Tuple[str, str], TileProfile] = {}  # for obs.breakdown
_warmed: Set[Tuple[str, str, int]] = set()        # (machine, kernel, n_tiles)
# known-bad configs (ISSUE-10): depths that failed under core.guard's ladder;
# choose_depth never re-proposes one (it halves past them, like the ladder)
_quarantined: Set[Tuple[str, str, int]] = set()   # (machine, kernel, depth)
_telemetry_on: bool = os.environ.get(TELEMETRY_ENV, "1") not in ("0", "off")


def _key(kernel: str, machine: Optional[MachineModel] = None) -> Tuple[str, str]:
    return ((machine or get_machine()).name, kernel)


# ------------------------------------------------------- per-kernel profiles
#
# flops_per_tile models the post-resumption work per element: pure data
# movement counts ~1 op/element (gather/triad), matmul tiles count 2*M*K*N.


def profile_row_gather(rows_per_tile: int, d: int, itemsize: int) -> TileProfile:
    """One tile = `rows_per_tile` single-row DMAs (an aset group)."""
    return TileProfile(
        tile_bytes=rows_per_tile * d * itemsize,
        flops_per_tile=float(rows_per_tile * d),
    )


def profile_span_gather(span: int, d: int, itemsize: int) -> TileProfile:
    """One tile = one coarse-grained span DMA (paper §III-C case 1)."""
    return TileProfile(
        tile_bytes=span * d * itemsize,
        flops_per_tile=float(span * d),
    )


def profile_scatter_add(rows_per_tile: int, d: int, itemsize: int) -> TileProfile:
    """RMW tile: rows are loaded AND stored (2x bytes), and each slot holds
    separate in/out buffers — tile_bytes doubles as both the traffic and the
    per-slot VMEM footprint."""
    return TileProfile(
        tile_bytes=2 * rows_per_tile * d * itemsize,
        flops_per_tile=float(2 * rows_per_tile * d),
    )


def profile_decode(blk: int, kh: int, g: int, d: int, itemsize: int) -> TileProfile:
    """KV block tile: k+v DMAs per slot; accumulators are depth-independent."""
    h = kh * g
    return TileProfile(
        tile_bytes=2 * blk * kh * d * itemsize,
        flops_per_tile=float(4 * blk * h * d),  # qk + pv per block
        shared_bytes=4 * (kh * g * (d + 2) + h * d),  # acc/m/l + q (f32)
    )


def profile_triad(rows: int, d: int, itemsize: int) -> TileProfile:
    """STREAM tile: two loads plus one store per slot (three slot buffers)."""
    return TileProfile(
        tile_bytes=3 * rows * d * itemsize,
        flops_per_tile=float(2 * rows * d),  # fma per element
    )


def profile_gmm(c: int, dm: int, f_tile: int, itemsize: int,
                *, f_total: int | None = None) -> TileProfile:
    """Streamed expert-weight tile; the token block AND the expert's full
    [c, f] output block are depth-independent VMEM residents."""
    return TileProfile(
        tile_bytes=dm * f_tile * itemsize,
        flops_per_tile=float(2 * c * dm * f_tile),
        shared_bytes=(c * dm + c * (f_total or f_tile)) * itemsize,
    )


def profile_ssd(chunk: int, nh: int, p: int, n: int, itemsize: int,
                *, seq_len: int | None = None) -> TileProfile:
    """Chunk tile: x/dt/B/C stream per slot; the recurrent state is
    sequential (one copy, depth-independent — core.context's SEQUENTIAL
    class) and the per-batch [seq, nh, p] y block is a shared resident."""
    return TileProfile(
        tile_bytes=chunk * (nh * p + nh + 2 * n) * itemsize,
        flops_per_tile=float(2 * chunk * chunk * (n + nh * p)),
        # f32 state + f32 h-out block + y output block
        shared_bytes=8 * nh * p * n + (seq_len or chunk) * nh * p * itemsize,
    )


# ------------------------------------------------------- run-time feedback


def record_transfer(kernel: str, seconds: float) -> None:
    """Feed one measured per-tile transfer latency into the feedback loop
    (stored under the active machine profile)."""
    with _lock:
        xs = _transfer_samples.setdefault(_key(kernel), [])
        xs.append(float(seconds))
        if len(xs) > MAX_SAMPLES_PER_KERNEL:
            del xs[: len(xs) - MAX_SAMPLES_PER_KERNEL]


def transfer_samples(kernel: str) -> List[float]:
    with _lock:
        return list(_transfer_samples.get(_key(kernel), ()))


def clear_samples(kernel: Optional[str] = None) -> None:
    """Drop recorded samples — and the depth decisions derived from them —
    for one kernel (active machine) or for everything (all machines)."""
    with _lock:
        if kernel is None:
            _transfer_samples.clear()
            _last_choice.clear()
            _last_mode.clear()
            _last_profile.clear()
            _warmed.clear()
            _quarantined.clear()
        else:
            k = _key(kernel)
            _transfer_samples.pop(k, None)
            _last_choice.pop(k, None)
            _last_mode.pop(k, None)
            _last_profile.pop(k, None)
            _warmed.difference_update(
                {w for w in _warmed if w[:2] == k})
            _quarantined.difference_update(
                {q for q in _quarantined if q[:2] == k})


# ------------------------------------------------------- config quarantine
#
# core.guard pushes every (machine, kernel, depth) that failed its ladder
# here; the decision path below halves past quarantined depths so a config
# that just crashed is never re-proposed (ISSUE-10).


def quarantine_config(kernel: str, depth: int,
                      machine: Optional[MachineModel] = None) -> None:
    """Mark (machine, kernel, depth) as known-bad."""
    with _lock:
        _quarantined.add((*_key(kernel, machine), int(depth)))


def is_quarantined(kernel: str, depth: int,
                   machine: Optional[MachineModel] = None) -> bool:
    with _lock:
        return (*_key(kernel, machine), int(depth)) in _quarantined


def quarantined_depths(kernel: str,
                       machine: Optional[MachineModel] = None) -> List[int]:
    k = _key(kernel, machine)
    with _lock:
        return sorted(d for (m, kn, d) in _quarantined if (m, kn) == k)


def clear_quarantine(kernel: Optional[str] = None) -> None:
    """Forget known-bad configs for one kernel (active machine) or all."""
    with _lock:
        if kernel is None:
            _quarantined.clear()
        else:
            k = _key(kernel)
            _quarantined.difference_update(
                {q for q in _quarantined if q[:2] == k})


def _avoid_quarantined(machine_name: str, kernel: str, depth: int) -> int:
    """Halve past quarantined depths, mirroring the guard's backoff ladder
    (so the solver's proposal and the ladder's landing spot agree)."""
    d = int(depth)
    while d > 1 and (machine_name, kernel, d) in _quarantined:
        d = max(1, d // 2)
    return d


def last_choice(kernel: str) -> Optional[int]:
    """Depth chosen by the most recent ``depth=None`` call for `kernel`
    under the active machine profile."""
    with _lock:
        return _last_choice.get(_key(kernel))


def last_profile(kernel: str) -> Optional[TileProfile]:
    """Tile profile of the most recent `choose_depth` call for `kernel`
    under the active machine (what `obs.breakdown` attributes against)."""
    with _lock:
        return _last_profile.get(_key(kernel))


def record_choice(kernel: str, depth: int) -> None:
    """Record the depth a kernel call actually ran with.

    `coro.coro_call` overwrites the solver's raw answer with the value it
    launched after clamping to the tile count, so `last_choice` reports an
    allocated depth, never an unreachable one.
    """
    with _lock:
        _last_choice[_key(kernel)] = int(depth)


# ----------------------------------------------------- always-on telemetry


def telemetry_enabled() -> bool:
    return _telemetry_on


def set_telemetry(on: bool) -> None:
    """Process-wide switch for the automatic pipeline timing hook
    (seeded from ``REPRO_TELEMETRY``; "0"/"off" disables)."""
    global _telemetry_on
    _telemetry_on = bool(on)


def observe_pipeline(kernel: str, wall_s: float, n_tiles: int) -> None:
    """One launched pipeline's wall clock -> the feedback store.

    Called by `core.coro.coro_call` after every completed pipeline and by
    the serving engines after every decode round, so `record_transfer` is
    fed from real runs without any caller wiring. The FIRST observation of
    a (machine, kernel, n_tiles) triple is dropped as compile warmup —
    jit/pallas tracing would otherwise dominate the tail and the adaptive
    re-solve would chase compilation, not transfer.
    """
    if not _telemetry_on or n_tiles <= 0 or wall_s < 0:
        return
    wkey = (*_key(kernel), int(n_tiles))
    with _lock:
        if wkey not in _warmed:
            _warmed.add(wkey)
            return
    record_transfer(kernel, wall_s / n_tiles)


def telemetry_summary() -> Dict[str, Any]:
    """Per-kernel feedback-loop state under the active machine profile.

    Returns ``{"machine": name, "kernels": {kernel: {samples, p50_us,
    p99_us, depth, mode, breakdown?}}}`` where `depth` is the depth the
    kernel last ran (`last_choice`), `mode` says whether that decision came
    from the static data-sheet solve or the adaptive re-solve over observed
    samples, and `breakdown` (present when both samples and a recorded tile
    profile exist) is `obs.breakdown.attribute`'s Fig. 14-style split of
    the observed p50 per-tile time into compute / exposed transfer /
    scheduling gap. Percentiles route through `obs.metrics.percentile` —
    the one shared implementation (ISSUE-8).

    This summary is also served as the ``autotune`` view of
    `obs.metrics.default_registry()`, so one registry snapshot covers the
    engine counters and the kernel feedback loop alike.

    The ``substrate`` section (ISSUE-10) folds in `core.guard.stats()` —
    guarded-vs-clean call counts, backoffs, fallbacks, parity mismatches,
    open breakers — plus the active machine's quarantined configs.
    """
    from repro.obs import breakdown as breakdown_mod  # local: obs ties back

    m = get_machine()
    with _lock:
        kernels = sorted({k for mk, k in _transfer_samples if mk == m.name}
                         | {k for mk, k in _last_choice if mk == m.name})
        out: Dict[str, Any] = {"machine": m.name, "kernels": {}}
        for kernel in kernels:
            key = (m.name, kernel)
            xs = _transfer_samples.get(key, [])
            entry: Dict[str, Any] = {
                "samples": len(xs),
                "depth": _last_choice.get(key),
                "mode": _last_mode.get(key, "static"),
            }
            if xs:
                p50_s = percentile(xs, 0.50)
                entry["p50_us"] = round(p50_s * 1e6, 3)
                entry["p99_us"] = round(percentile(xs, 0.99) * 1e6, 3)
                prof = _last_profile.get(key)
                if prof is not None:
                    entry["breakdown"] = breakdown_mod.attribute(
                        prof, _last_choice.get(key), p50_s, machine=m)
            out["kernels"][kernel] = entry
        quarantined = sorted(q for q in _quarantined if q[0] == m.name)
    from repro.core import guard  # local: guard imports this module
    out["substrate"] = guard.stats()
    out["substrate"]["quarantined"] = [
        {"kernel": kn, "depth": d} for (_, kn, d) in quarantined]
    return out


# ------------------------------------------------------------- the decision


def choose_depth(
    profile: TileProfile,
    *,
    kernel: Optional[str] = None,
    machine: Optional[MachineModel] = None,
    latency_s: Optional[float] = None,
    vmem_budget: Optional[int] = None,
    vars: Optional[Iterable[ctx_mod.VarSpec]] = None,
) -> int:
    """Solve the pipeline depth for one kernel call.

    `machine` defaults to the active `core.machine` profile and supplies
    the latency / VMEM budget / request-slot bounds (`latency_s` /
    `vmem_budget` override individually). With no recorded samples for
    (machine, kernel) this is exactly ``schedule.solve_depth`` — latency
    covered, VMEM capped, floor of 2. With samples (see `record_transfer`,
    `observe_pipeline`) it re-solves from the observed tail latency instead
    (`schedule.adaptive_depth`).

    When `vars` is given (the `CoroSpec` path: ``spec.all_vars()``) the VMEM
    cap is `context.max_depth(vars, vmem_budget)` — the §III-B classified
    context bytes (private x depth, shared/sequential x 1) — instead of the
    profile's hand-filled byte counts, with the machine's request slots as
    the hard cap. A shared accumulator therefore permits a deeper pipeline
    than the all-private baseline would.
    """
    m = machine or get_machine()
    budget = m.vmem_bytes if vmem_budget is None else vmem_budget
    vmem_cap = None
    if vars is not None:
        vmem_cap = ctx_mod.max_depth(list(vars), budget, cap=m.request_slots)
    if kernel:
        with _lock:
            samples = list(_transfer_samples.get((m.name, kernel), ()))
    else:
        samples = []
    if samples:
        mode = "adaptive"
        depth = adaptive_depth(profile, samples, machine=m,
                               vmem_budget=budget, vmem_cap=vmem_cap)
    else:
        mode = "static"
        depth = solve_depth(profile, machine=m, latency_s=latency_s,
                            vmem_budget=budget, vmem_cap=vmem_cap)
    if kernel is not None:
        with _lock:
            depth = _avoid_quarantined(m.name, kernel, depth)
        key = (m.name, kernel)
        with _lock:
            _last_choice[key] = depth
            _last_mode[key] = mode
            _last_profile[key] = profile
    return depth

"""Latency-aware depth autotuning for the coroutine kernels (CoroAMU §III-D).

This module is the glue between the depth solver (`core.schedule`) and the
kernel entry points (`kernels/*/ops.py`): every kernel family describes one
in-flight tile as a `TileProfile` (bytes DMA'd, flops after resumption, and
the VMEM its slot occupies), and `choose_depth` turns that profile into the
pipeline depth — the software analogue of the paper's Return-Block dynamic
scheduler picking how many coroutines to keep in flight.

Two paths:

* static solve — `choose_depth(profile)` with no recorded samples returns
  exactly `schedule.solve_depth(profile)`: the smallest depth that hides the
  modelled HBM latency, capped by the VMEM budget. Kernel entry points call
  this when invoked with ``depth=None``.
* run-time feedback — `record_transfer(kernel, seconds)` accumulates
  measured per-tile transfer latencies (benchmarks/kernel_bench.py feeds
  this); once samples exist for a kernel key, `choose_depth` re-solves from
  the observed tail latency via `schedule.adaptive_depth`, adapting the
  schedule to the latency actually seen instead of the data-sheet constant.

`last_choice(kernel)` exposes the most recent decision so benchmarks and
tests can report/assert the depth a ``depth=None`` call actually used.

API stability note: `TileProfile` is defined in `core.schedule` and
re-exported here. Kernel entry points built on `core.coro.coro_call`
derive their profile from the declarative `CoroSpec`
(``spec.profile()``) and pass ``vars=spec.all_vars()`` so the VMEM cap
comes from the classified context bytes; the ``profile_*`` helpers below
remain the standalone traffic/flops models used by benchmarks and the
modelled-latency figures.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional

from repro.core import context as ctx_mod
from repro.core.schedule import (
    HBM_LATENCY_S,
    VMEM_BYTES,
    TileProfile,
    adaptive_depth,
    solve_depth,
)

__all__ = [
    "TileProfile",
    "choose_depth",
    "clear_samples",
    "last_choice",
    "profile_decode",
    "profile_gmm",
    "profile_row_gather",
    "profile_scatter_add",
    "profile_span_gather",
    "profile_ssd",
    "profile_triad",
    "record_choice",
    "record_transfer",
    "transfer_samples",
]

_lock = threading.Lock()
_transfer_samples: Dict[str, List[float]] = {}
_last_choice: Dict[str, int] = {}


# ------------------------------------------------------- per-kernel profiles
#
# flops_per_tile models the post-resumption work per element: pure data
# movement counts ~1 op/element (gather/triad), matmul tiles count 2*M*K*N.


def profile_row_gather(rows_per_tile: int, d: int, itemsize: int) -> TileProfile:
    """One tile = `rows_per_tile` single-row DMAs (an aset group)."""
    return TileProfile(
        tile_bytes=rows_per_tile * d * itemsize,
        flops_per_tile=float(rows_per_tile * d),
    )


def profile_span_gather(span: int, d: int, itemsize: int) -> TileProfile:
    """One tile = one coarse-grained span DMA (paper §III-C case 1)."""
    return TileProfile(
        tile_bytes=span * d * itemsize,
        flops_per_tile=float(span * d),
    )


def profile_scatter_add(rows_per_tile: int, d: int, itemsize: int) -> TileProfile:
    """RMW tile: rows are loaded AND stored (2x bytes), and each slot holds
    separate in/out buffers — tile_bytes doubles as both the traffic and the
    per-slot VMEM footprint."""
    return TileProfile(
        tile_bytes=2 * rows_per_tile * d * itemsize,
        flops_per_tile=float(2 * rows_per_tile * d),
    )


def profile_decode(blk: int, kh: int, g: int, d: int, itemsize: int) -> TileProfile:
    """KV block tile: k+v DMAs per slot; accumulators are depth-independent."""
    h = kh * g
    return TileProfile(
        tile_bytes=2 * blk * kh * d * itemsize,
        flops_per_tile=float(4 * blk * h * d),  # qk + pv per block
        shared_bytes=4 * (kh * g * (d + 2) + h * d),  # acc/m/l + q (f32)
    )


def profile_triad(rows: int, d: int, itemsize: int) -> TileProfile:
    """STREAM tile: two loads plus one store per slot (three slot buffers)."""
    return TileProfile(
        tile_bytes=3 * rows * d * itemsize,
        flops_per_tile=float(2 * rows * d),  # fma per element
    )


def profile_gmm(c: int, dm: int, f_tile: int, itemsize: int,
                *, f_total: int | None = None) -> TileProfile:
    """Streamed expert-weight tile; the token block AND the expert's full
    [c, f] output block are depth-independent VMEM residents."""
    return TileProfile(
        tile_bytes=dm * f_tile * itemsize,
        flops_per_tile=float(2 * c * dm * f_tile),
        shared_bytes=(c * dm + c * (f_total or f_tile)) * itemsize,
    )


def profile_ssd(chunk: int, nh: int, p: int, n: int, itemsize: int,
                *, seq_len: int | None = None) -> TileProfile:
    """Chunk tile: x/dt/B/C stream per slot; the recurrent state is
    sequential (one copy, depth-independent — core.context's SEQUENTIAL
    class) and the per-batch [seq, nh, p] y block is a shared resident."""
    return TileProfile(
        tile_bytes=chunk * (nh * p + nh + 2 * n) * itemsize,
        flops_per_tile=float(2 * chunk * chunk * (n + nh * p)),
        # f32 state + f32 h-out block + y output block
        shared_bytes=8 * nh * p * n + (seq_len or chunk) * nh * p * itemsize,
    )


# ------------------------------------------------------- run-time feedback


def record_transfer(kernel: str, seconds: float) -> None:
    """Feed one measured per-tile transfer latency into the feedback loop."""
    with _lock:
        _transfer_samples.setdefault(kernel, []).append(float(seconds))


def transfer_samples(kernel: str) -> List[float]:
    with _lock:
        return list(_transfer_samples.get(kernel, ()))


def clear_samples(kernel: Optional[str] = None) -> None:
    with _lock:
        if kernel is None:
            _transfer_samples.clear()
        else:
            _transfer_samples.pop(kernel, None)


def last_choice(kernel: str) -> Optional[int]:
    """Depth chosen by the most recent ``depth=None`` call for `kernel`."""
    with _lock:
        return _last_choice.get(kernel)


def record_choice(kernel: str, depth: int) -> None:
    """Record the depth a kernel call actually ran with.

    `coro.coro_call` overwrites the solver's raw answer with the value it
    launched after clamping to the tile count, so `last_choice` reports an
    allocated depth, never an unreachable one.
    """
    with _lock:
        _last_choice[kernel] = int(depth)


# ------------------------------------------------------------- the decision


def choose_depth(
    profile: TileProfile,
    *,
    kernel: Optional[str] = None,
    latency_s: float = HBM_LATENCY_S,
    vmem_budget: int = VMEM_BYTES,
    vars: Optional[Iterable[ctx_mod.VarSpec]] = None,
) -> int:
    """Solve the pipeline depth for one kernel call.

    With no recorded samples for `kernel` this is exactly
    ``schedule.solve_depth(profile, latency_s=latency_s,
    vmem_budget=vmem_budget)`` — latency covered, VMEM capped, floor of 2.
    With samples (see `record_transfer`) it re-solves from the observed
    tail latency instead (`schedule.adaptive_depth`).

    When `vars` is given (the `CoroSpec` path: ``spec.all_vars()``) the VMEM
    cap is `context.max_depth(vars, vmem_budget)` — the §III-B classified
    context bytes (private x depth, shared/sequential x 1) — instead of the
    profile's hand-filled byte counts. A shared accumulator therefore
    permits a deeper pipeline than the all-private baseline would.
    """
    vmem_cap = None
    if vars is not None:
        vmem_cap = ctx_mod.max_depth(list(vars), vmem_budget)
    samples = transfer_samples(kernel) if kernel else []
    if samples:
        depth = adaptive_depth(profile, samples, vmem_budget=vmem_budget,
                               vmem_cap=vmem_cap)
    else:
        depth = solve_depth(profile, latency_s=latency_s,
                            vmem_budget=vmem_budget, vmem_cap=vmem_cap)
    if kernel is not None:
        with _lock:
            _last_choice[kernel] = depth
    return depth

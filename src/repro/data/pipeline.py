"""Deterministic, resumable, host-sharded data pipeline.

`batch_for_step(step)` is a pure function of (seed, step, shard) — restart at
any step reproduces the exact token stream with no iterator state to persist
(the checkpoint only stores the step counter). That property is what makes
checkpoint/restart exact (`runtime/fault_tolerance.run_with_restarts`
re-enters the step loop; template-based `checkpointing.restore` handles
elastic re-sharding).

The synthetic task is a fixed seeded Markov chain over the vocabulary, so
models have a real learnable signal with a known loss floor (the chain's
conditional entropy) — quickstart/train_100m show loss dropping toward it.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    branching: int = 4          # successors per token (lower = easier task)
    num_shards: int = 1         # data-parallel host count
    shard: int = 0


class MarkovTask:
    """Seeded bigram language with `branching` successors per token."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, b = cfg.vocab, cfg.branching
        self.succ = rng.integers(0, v, size=(v, b), dtype=np.int32)
        probs = rng.dirichlet(np.ones(b) * 2.0, size=v).astype(np.float64)
        self.probs = probs / probs.sum(-1, keepdims=True)

    def entropy(self) -> float:
        """Conditional entropy in nats — the achievable loss floor."""
        p = self.probs
        return float(-(p * np.log(p)).sum(-1).mean())

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % cfg.num_shards == 0
        local = cfg.global_batch // cfg.num_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.shard
        )
        toks = np.empty((local, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=local)
        for t in range(cfg.seq_len):
            cur = toks[:, t]
            u = rng.random(local)
            cum = self.probs[cur].cumsum(-1)
            choice = (u[:, None] < cum).argmax(-1)
            toks[:, t + 1] = self.succ[cur, choice]
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "positions": np.tile(np.arange(cfg.seq_len, dtype=np.int32), (local, 1)),
        }


class PrefetchIterator:
    """Background-thread prefetch of upcoming steps (overlap host datagen
    with device compute — the host-side analogue of the coroutine pipeline)."""

    def __init__(self, task: MarkovTask, start_step: int = 0, depth: int = 2):
        self.task = task
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.task.batch_for_step(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (device count locks at
# first init). REPRO_DRYRUN_DEVICES overrides for mini/CI runs.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import roofline
from repro.core.machine import get_machine
from repro.configs import (
    ALL_ARCH_NAMES,
    ALL_SHAPE_NAMES,
    SHAPES,
    batch_specs,
    cell_supported,
    decode_batch_specs,
    get_config,
)
from repro.launch.mesh import mesh_by_name
from repro.models import build_model
from repro.models import params as pm
from repro.optim import AdamWConfig
from repro.runtime.steps import (
    abstract_state,
    batch_shardings,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    state_shardings,
)
from repro.sharding import ShardingCtx


def _mem_dict(ma):
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _build_step(cfg, shape, mesh, rules=None, accum=1):
    """(fn, args, in_shardings, out_shardings) for one cell config."""
    ctx = ShardingCtx(mesh=mesh, rules=rules)
    model = build_model(cfg, ctx)
    kind = shape.kind
    if kind == "train":
        fn = make_train_step(model, AdamWConfig(), accum=accum)
        bspecs = batch_specs(cfg, shape)
        args = (abstract_state(model), bspecs)
        in_sh = (state_shardings(model), batch_shardings(ctx, bspecs))
        out_sh = (state_shardings(model), None)
    elif kind == "prefill":
        fn = make_prefill_step(model)
        bspecs = batch_specs(cfg, shape)
        args = (model.abstract_params(), bspecs)
        in_sh = (model.param_shardings(), batch_shardings(ctx, bspecs))
        out_sh = (model.cache_shardings(shape), None)
    else:  # decode
        fn = make_decode_step(model)
        bspecs = decode_batch_specs(cfg, shape)
        args = (model.abstract_params(), model.abstract_cache(shape), bspecs)
        in_sh = (model.param_shardings(), model.cache_shardings(shape),
                 batch_shardings(ctx, bspecs))
        out_sh = (None, model.cache_shardings(shape))
    return model, fn, args, in_sh, out_sh


def _compile(cfg, shape, mesh, rules=None, accum=1):
    model, fn, args, in_sh, out_sh = _build_step(cfg, shape, mesh, rules, accum)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    return {
        "model": model,
        "lowered": lowered,
        "compiled": compiled,
        "t_lower": t_lower,
        "t_compile": t_compile,
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "hbm": roofline.hbm_bytes(txt),
        "memory": _mem_dict(compiled.memory_analysis()),
        "collectives": roofline.collective_bytes(txt),
    }


def _depth_override(cfg, n: int):
    kw = {"n_layers": n, "scan_layers": False}
    if cfg.enc_dec:
        kw["n_enc_layers"] = n
    return cfg.replace(**kw)


def _extrapolate(c2: dict, c6: dict, L: int):
    """Linear-in-depth reconstruction: cost(L) = c2 + (L-2)/(6-2) * (c6-c2)."""
    f = (L - 2) / 4.0

    def lin(a, b):
        return max(a + f * (b - a), 0.0)

    coll_types = set(c2["collectives"]) | set(c6["collectives"])
    coll = {
        k: int(lin(c2["collectives"].get(k, 0), c6["collectives"].get(k, 0)))
        for k in coll_types
    }
    return {
        "flops": lin(c2["flops"], c6["flops"]),
        "bytes": lin(c2["bytes"], c6["bytes"]),
        "hbm": lin(c2["hbm"], c6["hbm"]),
        "collectives": coll,
    }


DEPTHS = (2, 6)


def run_cell(arch: str, shape_name: str, mesh_name: str, *, out_dir=None,
             overrides=None, rules=None, accum=1, verbose=True,
             full_unroll=False):
    """Lower + compile one (arch x shape x mesh) cell; return roofline record.

    Methodology (DESIGN.md §3.2): the FULL model is compiled with
    scan-over-layers — that run proves the sharding lowers and gives the real
    per-device memory analysis. Exact FLOPs / bytes / collective-bytes come
    from unrolled depth-2 and depth-6 compiles extrapolated linearly in L
    (XLA cost analysis counts loop bodies once, so scanned counts are wrong
    and full-depth unrolled compiles are prohibitively slow on one CPU core;
    `full_unroll=True` compiles the real thing for cross-validation).
    """
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=why)
        if out_dir is not None:
            out_dir = Path(out_dir)
            out_dir.mkdir(parents=True, exist_ok=True)
            tag = f"{arch}__{shape_name}__{mesh_name}"
            (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
        return rec

    if overrides:
        cfg = cfg.replace(**overrides)
    mesh = mesh_by_name(mesh_name)
    n_chips = mesh.devices.size
    kind = shape.kind
    L = cfg.n_layers

    # --- 1) full model, scanned: proves lowering + real memory analysis
    full = _compile(cfg.replace(scan_layers=True), shape, mesh, rules, accum)
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] full(scan) "
              f"lower={full['t_lower']:.1f}s compile={full['t_compile']:.1f}s")
        print("  memory_analysis:", full["memory"])

    # --- 2) depth-2 / depth-6 unrolled: exact per-layer costs
    if full_unroll:
        cx = _compile(cfg.replace(scan_layers=False), shape, mesh, rules)
        est = {"flops": cx["flops"], "bytes": cx["bytes"], "hbm": cx["hbm"],
               "collectives": cx["collectives"]}
        depth_info = {"mode": "full_unroll", "t_compile": cx["t_compile"]}
    else:
        c2 = _compile(_depth_override(cfg, DEPTHS[0]), shape, mesh, rules)
        c6 = _compile(_depth_override(cfg, DEPTHS[1]), shape, mesh, rules)
        est = _extrapolate(c2, c6, L)
        depth_info = {
            "mode": f"extrapolated_{DEPTHS[0]}_{DEPTHS[1]}",
            "d2": {"flops": c2["flops"], "bytes": c2["bytes"]},
            "d6": {"flops": c6["flops"], "bytes": c6["bytes"]},
        }

    # the roofline terms read the SAME machine model the depth solver uses
    # (core.machine's active profile; dial with REPRO_MACHINE)
    machine = get_machine()
    t = roofline.terms(est["flops"], est["bytes"], est["collectives"],
                       machine=machine)
    mflops = roofline.model_flops(cfg, shape, kind)

    rec.update(
        status="ok",
        machine=machine.name,
        kind=kind,
        chips=int(n_chips),
        compile_s=round(full["t_compile"], 2),
        memory=full["memory"],
        flops_scanned_per_chip=full["flops"],
        hlo_flops_per_chip=est["flops"],
        hlo_bytes_per_chip=est["bytes"],
        hbm_bytes_per_chip=est["hbm"],
        memory_hbm_s=est["hbm"] / machine.hbm_bw,
        collective_bytes=est["collectives"],
        terms=t,
        dominant=roofline.dominant(t),
        model_flops_total=mflops,
        model_flops_per_chip=mflops / n_chips,
        useful_flops_ratio=(mflops / n_chips) / est["flops"] if est["flops"] else 0.0,
        depth_info=depth_info,
    )
    if verbose:
        print("  est: flops=%.3e bytes=%.3e hbm=%.3e coll=%s"
              % (est["flops"], est["bytes"], est["hbm"], est["collectives"]))
        print("  terms: compute=%.3es memory=%.3es collective=%.3es dominant=%s"
              % (t["compute_s"], t["memory_s"], t["collective_s"], rec["dominant"]))
        print("  useful_flops_ratio=%.3f" % rec["useful_flops_ratio"])

    if out_dir is not None:
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}__{shape_name}__{mesh_name}"
        (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "mini", "both"])
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. remat=False)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding rule override logical=mesh_axis|none")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--full-unroll", action="store_true",
                    help="exact full-depth unrolled cost compile (slow)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = json.loads(v) if v not in ("True", "False") else (v == "True")
    rules = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rules[k] = None if v in ("none", "None") else v

    archs = list(ALL_ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(ALL_SHAPE_NAMES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                try:
                    rec = run_cell(arch, shape, mesh, out_dir=args.out,
                                   overrides=overrides or None,
                                   rules=rules or None, accum=args.accum,
                                   full_unroll=args.full_unroll)
                    if rec["status"] == "skipped":
                        print(f"[{arch} x {shape} x {mesh}] SKIPPED: {rec['reason']}")
                except Exception as e:  # record and continue
                    traceback.print_exc()
                    failures.append((arch, shape, mesh, repr(e)))
                    Path(args.out).mkdir(parents=True, exist_ok=True)
                    tag = f"{arch}__{shape}__{mesh}"
                    (Path(args.out) / f"{tag}.json").write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "error", "error": repr(e)}, indent=1))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("dry-run complete: all cells OK")


if __name__ == "__main__":
    main()

"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The single-pod mesh is 16x16 = 256 chips; the
multi-pod mesh is 2 pods x 256 = 512 chips with DP extended over the `pod`
axis (only gradient all-reduce crosses the pod/DCN boundary).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mini_mesh(devices: int = 8, model: int = 2):
    """Small host mesh for CI-style sharded tests (e.g. 8 CPU devices)."""
    data = devices // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def mesh_by_name(name: str):
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    if name.startswith("mini"):
        n = len(jax.devices())
        model = 2 if n % 2 == 0 else 1
        return make_mini_mesh(n, model)
    raise ValueError(f"unknown mesh {name!r}")

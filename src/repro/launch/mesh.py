"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The single-pod mesh is 16x16 = 256 chips; the
multi-pod mesh is 2 pods x 256 = 512 chips with DP extended over the `pod`
axis (only gradient all-reduce crosses the pod/DCN boundary).

Compatibility floor: jax >= 0.4.35 (for `jax.make_mesh`). `AxisType` only
exists from jax 0.5; on older versions (the pinned 0.4.37 environment) the
`axis_types` argument is omitted — every axis then defaults to the same
auto sharding behaviour, which is what we pass explicitly on newer jax.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mini_mesh(devices: int = 8, model: int = 2):
    """Small host mesh for CI-style sharded tests (e.g. 8 CPU devices)."""
    data = devices // model
    return _make_mesh((data, model), ("data", "model"))


def mesh_by_name(name: str):
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    if name.startswith("mini"):
        n = len(jax.devices())
        model = 2 if n % 2 == 0 else 1
        return make_mini_mesh(n, model)
    raise ValueError(f"unknown mesh {name!r}")

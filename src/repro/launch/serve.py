"""Serving launcher: batched prefill + decode with KV caches.

Two engines share one jitted, cache-donating decode-step discipline:

  dense - the fixed-batch baseline: one ``[batch, max_len]`` cache, one
          jitted `model.decode_step` reused for every token.
  paged - `repro.serve.PagedServingEngine`: continuous batching over a
          paged KV block pool, ragged prompt lengths, round width coupled
          to the autotuned coroutine depth.

Both report p50/p99 per-token latency alongside the aggregate
`decode_tok_per_s`.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --batch 4 --prompt-len 64 --gen 32 --engine paged
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, token_split
from repro.core import autotune, guard
from repro.core.machine import get_machine
from repro.models import build_model
from repro.obs import trace as obs_trace
from repro.obs.metrics import latency_report
from repro.sharding import NULL_CTX


def make_prompts(cfg, batch, prompt_len, rng):
    front, text = token_split(cfg, prompt_len)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, text)), jnp.int32),
        "positions": jnp.tile(jnp.arange(text, dtype=jnp.int32), (batch, 1)),
    }
    if cfg.enc_dec:
        b["frames"] = jnp.asarray(rng.normal(size=(batch, front, cfg.d_model)) * 0.02,
                                  jnp.bfloat16).astype(jnp.dtype(cfg.dtype))
    if cfg.vlm:
        b["patches"] = jnp.asarray(rng.normal(size=(batch, front, cfg.d_model)) * 0.02,
                                   jnp.bfloat16).astype(jnp.dtype(cfg.dtype))
    return b, text


def jit_decode_step(model):
    """The one jitted decode step every engine drive loop reuses: the cache
    is donated so each token updates it in place instead of copying."""
    return jax.jit(model.decode_step, donate_argnums=(1,))


def timed_decode_loop(decode, params, cache, tokens, *, steps, make_batch):
    """Drive `steps` decode calls through one jitted step, timing each.

    Returns (tokens_list, final_tokens, per-step latencies in seconds).
    Per-step sync is what makes p50/p99 meaningful; the cost is reported
    inside the latencies themselves rather than hidden.
    """
    out = [tokens]
    lat = []
    tracer = obs_trace.get_tracer()  # fetched once: null no-op when off
    for i in range(steps):
        t0 = time.perf_counter()
        with tracer.span("decode_step", step=i, batch=int(tokens.shape[0])):
            logits, cache = decode(params, cache, make_batch(tokens, i))
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
        lat.append(dt)
        # always-on numerics policing (DESIGN.md §2.7): the dense loop has
        # no twin to fall back to, so a non-finite step raises under
        # --strict and is counted (substrate.numerics_faults) otherwise
        nerr = guard.scan_output("serve_dense_decode", logits)
        if nerr is not None and guard.strict_mode():
            raise nerr
        if autotune.telemetry_enabled():
            # one "tile" per request token this step; the first observation
            # (jit compile) is dropped by observe_pipeline's warmup skip
            autotune.observe_pipeline("serve_dense_decode", dt,
                                      int(tokens.shape[0]))
        out.append(tokens)
    return out, tokens, lat


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          greedy: bool = True, ctx=NULL_CTX, layout: str = "default",
          engine: str = "dense", block_size: int = 16,
          num_blocks: int | None = None, prefix_cache: bool = True,
          prefill_chunk: int = 32, deadline_s: float | None = None,
          chaos: int | None = None, strict: bool = False):
    if strict:
        # CI parity lanes: no silent degradation — a substrate fault raises
        guard.set_strict(True)
    if layout == "serving":
        from repro.runtime.layouts import serving_config_overrides
        cfg = cfg.replace(**serving_config_overrides())
        # (rules take effect when ctx carries a mesh; see runtime.layouts)
    if engine == "paged":
        return serve_paged(cfg, batch=batch, prompt_len=prompt_len, gen=gen,
                           seed=seed, ctx=ctx, block_size=block_size,
                           num_blocks=num_blocks, prefix_cache=prefix_cache,
                           prefill_chunk=prefill_chunk, deadline_s=deadline_s,
                           chaos=chaos)
    if deadline_s is not None or chaos is not None:
        raise ValueError("--deadline-s / --chaos need --engine paged (the "
                         "dense baseline has no per-request lifecycle)")
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts, text_len = make_prompts(cfg, batch, prompt_len, rng)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, pad_to=text_len + gen))
    decode = jit_decode_step(model)

    t0 = time.perf_counter()
    cache, logits = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    def make_batch(tokens, i):
        return {"tokens": tokens,
                "positions": jnp.full((batch, 1), text_len + i, jnp.int32)}

    out, tokens, lat = timed_decode_loop(decode, params, cache, tokens,
                                         steps=gen - 1, make_batch=make_batch)
    t_decode = sum(lat)

    generated = jnp.concatenate(out, axis=1)
    stats = {
        "engine": "dense",
        "machine": get_machine().name,
        "generated_shape": tuple(generated.shape),
        "prefill_s": round(t_prefill, 3),
        "decode_s": round(t_decode, 3),
        "decode_tok_per_s": round(batch * (gen - 1) / max(t_decode, 1e-9), 1),
        "sample_tokens": np.asarray(generated[0, :8]).tolist(),
        "substrate": guard.stats(),
    }
    stats.update(latency_report(lat))
    return stats


def serve_paged(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
                ctx=NULL_CTX, block_size: int = 16,
                num_blocks: int | None = None, prefix_cache: bool = True,
                prefill_chunk: int = 32, deadline_s: float | None = None,
                chaos: int | None = None):
    """Continuous batching: `batch` requests with ragged prompt lengths
    (4x spread) through a block pool sized to force page reuse. Half the
    requests share a system-prompt prefix so the prefix cache (when on) has
    something to dedup. `deadline_s` bounds each request's wall clock;
    `chaos` seeds a deterministic fault schedule (serve.FaultInjector) so
    the run doubles as a robustness drill — the stats then report how many
    requests degraded (cancelled/failed/stalled) instead of completing."""
    from repro.serve import FaultInjector, PagedServingEngine

    faults = FaultInjector(chaos) if chaos is not None else None
    rng = np.random.default_rng(seed)
    lo = max(1, prompt_len // 4)
    plens = [int(x) for x in rng.integers(lo, prompt_len + 1, batch)]
    plens[int(np.argmax(plens))] = prompt_len  # keep the nominal worst case

    blocks_per_req = -(-(prompt_len + gen) // block_size)
    if num_blocks is None:
        # roughly half the requests resident at once: completions must free
        # pages for later admissions (the continuous-batching regime)
        num_blocks = blocks_per_req * max(2, (batch + 1) // 2)

    system = rng.integers(0, cfg.vocab, max(lo // 2, 1))
    eng = PagedServingEngine(cfg, ctx, block_size=block_size,
                             num_blocks=num_blocks, seed=seed,
                             prefix_cache=prefix_cache,
                             prefill_chunk=prefill_chunk,
                             deadline_s=deadline_s, faults=faults)
    for i, plen in enumerate(plens):
        body = rng.integers(0, cfg.vocab, plen)
        if i % 2 == 0:  # every other request opens with the system prompt
            body[: len(system)] = system[: plen]
        eng.submit(body, max_new_tokens=gen)
    stats = eng.run()
    stats["prompt_lens"] = plens
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--layout", default="default", choices=["default", "serving"])
    ap.add_argument("--engine", default="dense", choices=["dense", "paged"])
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None)
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="share KV pages across common prompt prefixes "
                         "(paged engine; --no-prefix-cache disables)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="tokens per chunked-prefill step (paged engine)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline in seconds; "
                         "expired requests are CANCELLED at the next round "
                         "boundary (paged engine)")
    ap.add_argument("--strict", action="store_true",
                    help="disable substrate degradation: any kernel "
                         "backoff/fallback/parity mismatch raises its typed "
                         "SubstrateError instead (CI parity lanes)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject a deterministic fault schedule (pool "
                         "exhaustion, reclaim refusal, step exceptions, "
                         "latency spikes) seeded by SEED (paged engine)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export the run's span trace as Chrome trace-event "
                         "JSON (open in https://ui.perfetto.dev)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                  gen=args.gen, layout=args.layout, engine=args.engine,
                  block_size=args.block_size, num_blocks=args.num_blocks,
                  prefix_cache=args.prefix_cache,
                  prefill_chunk=args.prefill_chunk,
                  deadline_s=args.deadline_s, chaos=args.chaos,
                  strict=args.strict)
    if args.trace:
        stats["trace"] = obs_trace.get_tracer().export(args.trace)
        stats["trace_events"] = len(obs_trace.get_tracer().events)
    print(json.dumps(stats))
    return stats


if __name__ == "__main__":
    main()

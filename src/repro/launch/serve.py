"""Serving launcher: batched prefill + decode with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, token_split
from repro.models import build_model
from repro.sharding import NULL_CTX


def make_prompts(cfg, batch, prompt_len, rng):
    front, text = token_split(cfg, prompt_len)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, text)), jnp.int32),
        "positions": jnp.tile(jnp.arange(text, dtype=jnp.int32), (batch, 1)),
    }
    if cfg.enc_dec:
        b["frames"] = jnp.asarray(rng.normal(size=(batch, front, cfg.d_model)) * 0.02,
                                  jnp.bfloat16).astype(jnp.dtype(cfg.dtype))
    if cfg.vlm:
        b["patches"] = jnp.asarray(rng.normal(size=(batch, front, cfg.d_model)) * 0.02,
                                   jnp.bfloat16).astype(jnp.dtype(cfg.dtype))
    return b, text


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          greedy: bool = True, ctx=NULL_CTX, layout: str = "default"):
    if layout == "serving":
        from repro.runtime.layouts import serving_config_overrides
        cfg = cfg.replace(**serving_config_overrides())
        # (rules take effect when ctx carries a mesh; see runtime.layouts)
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts, text_len = make_prompts(cfg, batch, prompt_len, rng)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, pad_to=text_len + gen))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    cache, logits = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tokens]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        dbatch = {"tokens": tokens,
                  "positions": jnp.full((batch, 1), text_len + i, jnp.int32)}
        logits, cache = decode(params, cache, dbatch)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    generated = jnp.concatenate(out, axis=1)
    return {
        "generated_shape": tuple(generated.shape),
        "prefill_s": round(t_prefill, 3),
        "decode_s": round(t_decode, 3),
        "decode_tok_per_s": round(batch * (gen - 1) / max(t_decode, 1e-9), 1),
        "sample_tokens": np.asarray(generated[0, :8]).tolist(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--layout", default="default", choices=["default", "serving"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                  gen=args.gen, layout=args.layout)
    print(json.dumps(stats))
    return stats


if __name__ == "__main__":
    main()

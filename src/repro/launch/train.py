"""Training launcher.

CPU-scale entry point (full-scale runs go through the same code with the
production mesh): picks an arch (reduced or custom dims), builds the Markov
data task, and runs the fault-tolerant train loop.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --reduced \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime.train_loop import train
from repro.sharding import NULL_CTX, ShardingCtx


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU)")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash (fault-tolerance demo)")
    ap.add_argument("--mesh", default="none", choices=["none", "mini"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model,
                    n_heads=max(args.d_model // 64, 1),
                    kv_heads=max(args.d_model // 128, 1),
                    d_ff=args.d_model * 4, head_dim=0)
    if args.layers:
        over["n_layers"] = args.layers
    if args.vocab:
        over["vocab"] = args.vocab
    if over:
        cfg = cfg.replace(**over)

    ctx = NULL_CTX
    if args.mesh == "mini":
        from repro.launch.mesh import mesh_by_name
        ctx = ShardingCtx(mesh=mesh_by_name("mini"))

    model = build_model(cfg, ctx)
    print(f"arch={cfg.name} params={model.n_params()/1e6:.1f}M "
          f"layers={cfg.n_layers} d_model={cfg.d_model}")

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    report = train(
        model, steps=args.steps, data_cfg=data_cfg,
        opt=AdamWConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 5)),
        accum=args.accum, compress_grads=args.compress_grads,
        ckpt_dir=args.ckpt_dir, fail_at_step=args.fail_at,
    )
    first = min(report.losses)
    last = max(report.losses)
    print(json.dumps({
        "steps": report.steps,
        "loss_first": report.losses[first],
        "loss_last": report.losses[last],
        "resumed_from": report.resumed_from,
        "stragglers": report.straggler_steps,
        "wall_s": round(report.wall_s, 1),
    }))
    return report


if __name__ == "__main__":
    main()

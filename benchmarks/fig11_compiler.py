"""Fig. 11: prefetch-based CoroAMU compiler vs hand-written coroutines on x86.

Paper numbers (Intel Xeon Gold 6130, local/NUMA = 90/130ns): SOTA coroutines
average 1.40x / 2.01x; the CoroAMU compiler 2.11x / 2.78x (1.51x relative).
"""
from __future__ import annotations

from repro.core import sim
from benchmarks.common import csv_table


def rows():
    out = []
    for lat, tag in ((90, "local"), (130, "numa")):
        for variant in ("coroutine", "coroamu-s"):
            per = {}
            for name, b in sim.BENCHES.items():
                n = sim.best_coros(variant, b, latency_ns=lat, ua=sim.SKYLAKE)
                per[name] = sim.speedup(variant, b, latency_ns=lat,
                                        n_coros=n, ua=sim.SKYLAKE)
            avg = sim.geomean(list(per.values()))
            out.append([tag, variant, *(round(per[n], 2) for n in sim.BENCHES),
                        round(avg, 2)])
    return out


def table() -> str:
    return csv_table(["memory", "variant", *sim.BENCHES, "geomean"], rows())


def headline():
    vals = {}
    for lat, tag in ((90, "local"), (130, "numa")):
        for variant in ("coroutine", "coroamu-s"):
            vals[(tag, variant)] = sim.average_speedup(
                variant, latency_ns=lat, ua=sim.SKYLAKE, tune_coros=True)
    return vals


if __name__ == "__main__":
    print(table())

"""Fig. 15: compiler-optimization ablation at 100ns — (1) CoroAMU-D+bafin,
(2) + context minimization, (3) + request aggregation.

Reports normalized performance, normalized switch count, and context
operations per switch. Paper: gains up to ~20% (GUPS/IS/HJ context; mcf/HJ/
lbm/STREAM aggregation). The kernel-level twin of (3) is the coalescing
planner (core.descriptors) exercised by kernel_bench.py.
"""
from __future__ import annotations

from repro.core import sim
from benchmarks.common import csv_table

STAGES = (
    ("bafin", dict(ctx_opt=False, coalesce=False)),
    ("+context", dict(ctx_opt=True, coalesce=False)),
    ("+aggregation", dict(ctx_opt=True, coalesce=True)),
)


def rows():
    out = []
    for name, b in sim.BENCHES.items():
        base = sim.simulate("coroamu-full", b, latency_ns=100, n_coros=96,
                            **STAGES[0][1]).cycles_per_iter
        for tag, kw in STAGES:
            r = sim.simulate("coroamu-full", b, latency_ns=100, n_coros=96, **kw)
            switches = b.accesses
            if kw["coalesce"]:
                switches = b.accesses * max(
                    1 - (b.coalesce_spatial + b.coalesce_indep), 0.15)
            ctx_words = b.context_words_opt if kw["ctx_opt"] else b.context_words
            out.append([name, tag,
                        round(base / r.cycles_per_iter, 3),
                        round(switches / b.accesses, 3),
                        2 * ctx_words])
    return out


def table() -> str:
    return csv_table(
        ["bench", "stage", "perf_norm", "switches_norm", "ctx_ops_per_switch"],
        rows())


if __name__ == "__main__":
    print(table())

"""Fig. 14: execution-cycle breakdown at 200ns for (1) serial, (2) CoroAMU-D,
(3) CoroAMU-D + bafin.

Paper: scheduler branch mispredicts cost >15% of CoroAMU-D cycles on average;
bafin eliminates them.
"""
from __future__ import annotations

import statistics

from repro.core import sim
from benchmarks.common import csv_table

CONFIGS = (
    ("serial", {}),
    ("coroamu-d", {}),
    ("coroamu-d+bafin", {}),
)


def _simulate(tag, bench):
    if tag == "coroamu-d+bafin":
        # bafin removes the mispredict penalty but keeps -D codegen
        r = sim.simulate("coroamu-full", bench, latency_ns=200, n_coros=96,
                         ctx_opt=False, coalesce=False)
    else:
        r = sim.simulate(tag, bench, latency_ns=200, n_coros=96)
    return r


def rows():
    out = []
    for tag, _ in CONFIGS:
        for name, b in sim.BENCHES.items():
            r = _simulate(tag, b)
            out.append([tag, name,
                        round(r.breakdown["compute"], 3),
                        round(r.breakdown["scheduler"], 3),
                        round(r.breakdown["context"], 3),
                        round(r.breakdown["mispredict"], 3),
                        round(r.breakdown["stall"], 3)])
    return out


def mean_mispredict() -> float:
    return statistics.mean(
        _simulate("coroamu-d", b).breakdown["mispredict"]
        for b in sim.BENCHES.values())


def table() -> str:
    return csv_table(
        ["config", "bench", "compute", "scheduler", "context", "mispredict", "stall"],
        rows())


if __name__ == "__main__":
    print(table())
    print(f"# mean CoroAMU-D mispredict fraction: {mean_mispredict():.2f} (paper: >0.15)")

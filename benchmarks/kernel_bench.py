"""Kernel micro-benchmarks: shape sweeps in interpret mode + coalescing stats.

Interpret-mode wall time is NOT TPU performance (the kernels target TPU; this
container is CPU) — the derived columns that matter are correctness vs the
oracle, the coalescing ratio (requests saved, paper §III-C), and the
latency-aware depth the scheduler solves (paper §III-D analogue).

This is also the run-time feedback producer for the autotuner: measured
per-tile transfer samples are fed to `core.autotune.record_transfer`, and
the adaptive re-solve (`schedule.adaptive_depth`, the software analogue of
the paper's Return-Block dynamic scheduler) is reported next to the static
choice.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_table, timed
from repro.core import autotune, guard
from repro.core.descriptors import plan_gather
from repro.core.machine import get_machine
from repro.core.schedule import TileProfile, solve_depth, achieved_bandwidth
from repro.kernels.coro_gather.coro_gather import row_gather_spec
from repro.kernels.coro_gather.ops import coro_gather
from repro.kernels.coro_gather.ref import gather_ref
from repro.kernels.coro_scatter_add.coro_scatter_add import scatter_add_spec
from repro.kernels.decode_attention.decode_attention import (
    decode_spec,
    paged_decode_spec,
)
from repro.kernels.decode_attention.ops import (
    decode_attention,
    paged_decode_attention,
)
from repro.kernels.moe_gmm.moe_gmm import gmm_spec
from repro.kernels.ssd_scan.ssd_scan import ssd_spec
from repro.kernels.stream_copy.ops import stream_triad
from repro.kernels.stream_copy.ref import triad_ref
from repro.kernels.stream_copy.stream_copy import triad_spec


def gather_rows():
    rng = np.random.RandomState(0)
    out = []
    for n_rows, d, n_idx in ((512, 128, 256), (2048, 256, 512)):
        table = jnp.asarray(rng.randn(n_rows, d), jnp.float32)
        idx = jnp.asarray(rng.randint(0, n_rows, n_idx), jnp.int32)
        res, us = timed(coro_gather, table, idx, repeats=1)
        ok = bool(jnp.allclose(res, gather_ref(table, idx)))
        depth = autotune.last_choice("row_gather")
        out.append(["coro_gather", f"{n_rows}x{d}/{n_idx}", round(us, 1), ok,
                    depth])
    return out


def coalesce_rows():
    rng = np.random.RandomState(1)
    out = []
    patterns = {
        "gups_random": rng.randint(0, 4096, 512),
        "stream_unit": np.arange(512),
        "hj_mixed": np.concatenate([np.arange(100, 300),
                                    rng.randint(0, 4096, 312)]),
    }
    for name, idx in patterns.items():
        plan = plan_gather(idx, span=8)
        out.append(["coalesce", name, plan.n_requests,
                    plan.requests_issued(), round(plan.coalescing_ratio(), 3)])
    return out


def schedule_rows():
    out = []
    for tag, tile_bytes, flops in (("gather_row", 8 * 2048 * 4, 64 * 8),
                                   ("kv_block", 2 * 128 * 8 * 128 * 2, 4 * 128 * 96 * 128),
                                   ("stream_tile", 2 * 128 * 512 * 4, 128 * 512)):
        p = TileProfile(tile_bytes=tile_bytes, flops_per_tile=float(flops))
        d = solve_depth(p)
        bw = achieved_bandwidth(p, d) / 1e9
        bw2 = achieved_bandwidth(p, 2) / 1e9
        out.append(["depth_solver", tag, d, round(bw, 1), round(bw2, 1)])
    return out


def adaptive_rows():
    """Feed measured per-tile transfer samples back into the autotuner.

    On this CPU container the 'measured latency' is interpret-mode overhead,
    orders slower than real HBM — which is exactly what makes the row useful:
    it shows the feedback path re-solving to a deeper (request-slot-capped)
    pipeline when observed latency dwarfs the data-sheet constant. The tile
    is big enough that the static solve sits below the cap, so the gap is
    visible.
    """
    rng = np.random.RandomState(3)
    table = jnp.asarray(rng.randn(512, 512), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 512, 64), jnp.int32)
    rows_per_tile = 8
    profile = autotune.profile_row_gather(rows_per_tile, 512, 4)
    static = autotune.choose_depth(profile, kernel="row_gather_bench")

    n_tiles = idx.shape[0] // rows_per_tile
    autotune.clear_samples("row_gather_bench")
    for _ in range(5):
        t0 = time.perf_counter()
        coro_gather(table, idx, rows_per_tile=rows_per_tile).block_until_ready()
        per_tile = (time.perf_counter() - t0) / n_tiles
        autotune.record_transfer("row_gather_bench", per_tile)
    adaptive = autotune.choose_depth(profile, kernel="row_gather_bench")
    n = len(autotune.transfer_samples("row_gather_bench"))
    autotune.clear_samples("row_gather_bench")
    return [["adaptive_depth", "row_gather", n, static, adaptive]]


def context_rows():
    """Derived context per kernel family (the §III-B classification at work).

    For each declared `CoroSpec`: the depth the autotuner solves from the
    spec, the classified context bytes at that depth, and the all-private
    baseline a conventional coroutine frame would occupy (Fig. 15's
    comparison) — the shared/sequential savings ratio in the last column.
    """
    f32 = jnp.float32
    specs = (
        row_gather_spec(8, 128, f32),
        scatter_add_spec(8, 128, f32),
        decode_spec(128, 8, 12, 128, f32),
        gmm_spec(64, 512, 128, f32, f_total=2048),
        ssd_spec(64, 8, 64, 128, f32, seq_len=2048),
        triad_spec(128, 512, f32),
    )
    out = []
    for spec in specs:
        depth = autotune.choose_depth(spec.profile(), vars=spec.all_vars())
        opt = spec.context_bytes(depth)
        base = spec.context_bytes(depth, baseline=True)
        out.append([spec.name, depth, opt, base, round(opt / base, 3)])
    return out


def paged_decode_rows():
    """Paged vs dense decode kernel at EQUAL total KV.

    The same [B, S] worth of KV is served once as dense per-request caches
    and once as a shuffled block pool addressed through block tables. The
    row reports the paged spec's classified context bytes, the depth the
    autotuner solves for it, and interpret-mode tokens/s for both kernels
    (relative, not TPU numbers — see module docstring).
    """
    rng = np.random.RandomState(4)
    out = []
    for bsz, s, kh, h, d, blk in ((2, 256, 2, 8, 16, 64),):
        q = jnp.asarray(rng.randn(bsz, h, d), jnp.float32)
        k = jnp.asarray(rng.randn(bsz, s, kh, d), jnp.float32)
        v = jnp.asarray(rng.randn(bsz, s, kh, d), jnp.float32)
        # carve the same KV into a block pool with shuffled page placement
        m = s // blk
        nb = bsz * m + 1  # + garbage page 0
        ids = rng.permutation(np.arange(1, nb)).reshape(bsz, m)
        kp = jnp.zeros((nb, blk, kh, d), jnp.float32)
        vp = jnp.zeros((nb, blk, kh, d), jnp.float32)
        kp = kp.at[ids.reshape(-1)].set(k.reshape(bsz * m, blk, kh, d))
        vp = vp.at[ids.reshape(-1)].set(v.reshape(bsz * m, blk, kh, d))
        bt = jnp.asarray(ids, jnp.int32)
        lens = jnp.full((bsz,), s, jnp.int32)

        _, us_dense = timed(decode_attention, q, k, v, s - 1, blk=blk, repeats=1)
        res, us_paged = timed(paged_decode_attention, q, kp, vp, bt, lens,
                              repeats=1)
        ref = decode_attention(q, k, v, s - 1, blk=blk)
        assert bool(jnp.allclose(res, ref, rtol=2e-5, atol=2e-5))
        g = h // kh
        spec = paged_decode_spec(blk, kh, g, d, jnp.float32, m)
        depth = autotune.last_choice("paged_decode")
        out.append(["paged_decode", f"{bsz}x{s}x{kh}x{d}/blk{blk}",
                    spec.context_bytes(depth), depth,
                    round(bsz / (us_paged * 1e-6), 1),
                    round(bsz / (us_dense * 1e-6), 1)])
    return out


def prefix_decode_rows():
    """Shared-prefix serving at EQUAL KV budget, cache warm vs cold.

    The same 6-request workload (all opening with a 3-block system prompt)
    runs through the paged engine twice — prefix cache on, then off — with
    identical pool size and params. The row reports the warm run's hit rate,
    pages dedup'd, and decode tokens/s for both runs (interpret-mode
    relative numbers; the dedup counters are the point)."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import PagedServingEngine

    cfg = get_config("yi-6b").reduced().replace(dtype="float32",
                                                param_dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(5)
    blk, gen = 4, 6
    shared = list(rng.randint(0, cfg.vocab, 3 * blk))
    prompts = [shared + list(rng.randint(0, cfg.vocab, 3 + i))
               for i in range(6)]

    def run(prefix_cache):
        eng = PagedServingEngine(cfg, block_size=blk, num_blocks=48,
                                 params=params, max_in_flight=2,
                                 prefix_cache=prefix_cache)
        for p in prompts:
            eng.submit(p, max_new_tokens=gen)
        return eng.run()

    warm, cold = run(True), run(False)
    hit_rate = warm["prefix_hits"] / max(warm["requests"], 1)
    return [["prefix_decode", f"{len(prompts)}req/blk{blk}",
             round(hit_rate, 3), warm["blocks_shared"],
             f"{warm['blocks_allocated']}/{cold['blocks_allocated']}",
             warm["decode_tok_per_s"], cold["decode_tok_per_s"]]]


def triad_rows():
    rng = np.random.RandomState(2)
    b = jnp.asarray(rng.randn(1024, 64), jnp.float32)
    c = jnp.asarray(rng.randn(1024, 64), jnp.float32)
    res, us = timed(stream_triad, b, c, 2.5, repeats=1)
    # atol: fma reassociation leaves ~1e-6 absolute noise on near-zero entries
    ok = bool(jnp.allclose(res, triad_ref(b, c, 2.5), rtol=1e-5, atol=1e-5))
    return [["stream_triad", "1024x64", round(us, 1), ok,
             autotune.last_choice("stream_triad")]]


def _json_workloads():
    """One small run per kernel family: (spec for the static solve, thunk).

    Shapes mirror what the thunk actually launches so the static depth and
    the telemetry entry describe the same tile.
    """
    from repro.kernels.coro_scatter_add.ops import coro_scatter_add
    from repro.kernels.moe_gmm.ops import moe_gmm
    from repro.kernels.ssd_scan.ops import ssd

    rng = np.random.RandomState(7)
    f32 = jnp.float32

    table_g = jnp.asarray(rng.randn(256, 128), f32)
    idx_g = jnp.asarray(rng.randint(0, 256, 64), jnp.int32)

    table_s = jnp.asarray(rng.randn(256, 128), f32)
    idx_s = rng.randint(0, 256, 32)
    upd_s = jnp.asarray(rng.randn(32, 128), f32)

    q = jnp.asarray(rng.randn(2, 8, 16), f32)
    k = jnp.asarray(rng.randn(2, 128, 2, 16), f32)
    v = jnp.asarray(rng.randn(2, 128, 2, 16), f32)

    xs = jnp.asarray(rng.randn(2, 16, 64), f32)
    w = jnp.asarray(rng.randn(2, 64, 256), f32)

    x = jnp.asarray(rng.randn(1, 128, 2, 8), f32)
    dt = jnp.asarray(rng.rand(1, 128, 2), f32)
    A = jnp.asarray(-np.abs(rng.randn(2)), f32)
    B = jnp.asarray(rng.randn(1, 128, 16), f32)
    C = jnp.asarray(rng.randn(1, 128, 16), f32)

    tb = jnp.asarray(rng.randn(256, 64), f32)
    tc = jnp.asarray(rng.randn(256, 64), f32)

    return [
        (row_gather_spec(8, 128, f32),
         lambda: coro_gather(table_g, idx_g)),
        (scatter_add_spec(8, 128, f32),
         lambda: coro_scatter_add(table_s, idx_s, upd_s)),
        (decode_spec(64, 2, 4, 16, f32),
         lambda: decode_attention(q, k, v, 127, blk=64)),
        (gmm_spec(16, 64, 128, f32, f_total=256),
         lambda: moe_gmm(xs, w, f_tile=128)),
        (ssd_spec(64, 2, 8, 16, f32, seq_len=128),
         lambda: ssd(x, dt, A, B, C, chunk=64)),
        (triad_spec(128, 64, f32),
         lambda: stream_triad(tb, tc, 2.5)),
    ]


def json_report() -> dict:
    """Machine-stamped report (ISSUE-6 CI lane): active profile, per-kernel
    static solve vs the depth actually run, and observed p99 per-tile latency
    from the always-on telemetry. Each workload runs twice — the first run is
    compile warmup (dropped by the warmup skip), the second records.

    ISSUE-8 additions: each kernel carries the Fig. 14-style stall
    `breakdown` (compute / exposed transfer / scheduling gap attribution of
    its observed per-tile time against the active `MachineModel`), and the
    report embeds the default `obs.metrics` registry snapshot — the
    real-v5e measurement run reads hardware truth through this one report.

    ISSUE-10: the top-level `substrate` section is `core.guard.stats()` —
    guarded vs clean call counts, backoffs, fallbacks, parity checks. Under
    `--strict` a clean bench must show zero backoffs/fallbacks (the CI lane
    asserts it); anything else means the substrate degraded silently.
    """
    from repro.obs import metrics as obs_metrics

    m = get_machine()
    workloads = _json_workloads()
    for _, run in workloads:
        run()
        run()
    summ = autotune.telemetry_summary()
    kernels = {}
    for spec, _ in workloads:
        t = summ["kernels"].get(spec.name, {})
        # choose_depth AFTER the runs so it reports the static solve without
        # disturbing the telemetry the runs recorded
        kernels[spec.name] = {
            "static_depth": autotune.choose_depth(spec.profile(),
                                                  vars=spec.all_vars()),
            "ran_depth": t.get("depth"),
            "mode": t.get("mode"),
            "samples": t.get("samples", 0),
            "observed_p99_us": t.get("p99_us"),
            "breakdown": t.get("breakdown"),
        }
    return {"machine": m.name, "profile": m.summary(), "kernels": kernels,
            "substrate": guard.stats(),
            "metrics": obs_metrics.default_registry().snapshot()}


def table() -> str:
    s = csv_table(["kernel", "shape", "us_per_call", "allclose", "auto_depth"],
                  gather_rows() + triad_rows())
    s += csv_table(["pass", "pattern", "requests", "issued", "ratio"],
                   coalesce_rows())
    s += csv_table(["pass", "tile", "depth", "GBps_at_depth", "GBps_at_2"],
                   schedule_rows())
    s += csv_table(["pass", "kernel", "samples", "static_depth", "adaptive_depth"],
                   adaptive_rows())
    s += csv_table(["spec", "depth", "ctx_bytes", "ctx_baseline", "ratio"],
                   context_rows())
    s += csv_table(["pass", "shape", "ctx_bytes", "depth", "tok_per_s",
                    "dense_tok_per_s"], paged_decode_rows())
    s += csv_table(["pass", "workload", "hit_rate", "blocks_shared",
                    "alloc_warm/cold", "tok_per_s_warm", "tok_per_s_cold"],
                   prefix_decode_rows())
    return s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="machine-stamped JSON report instead of CSV tables")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export the bench run's span trace as Chrome "
                         "trace-event JSON (open in https://ui.perfetto.dev)")
    ap.add_argument("--strict", action="store_true",
                    help="disable substrate degradation: any kernel "
                         "backoff/fallback/parity mismatch raises its typed "
                         "SubstrateError instead (CI parity lanes)")
    args = ap.parse_args(argv)
    if args.strict:
        guard.set_strict(True)
    if args.json:
        print(json.dumps(json_report(), indent=2))
    else:
        print(table())
    if args.trace:
        from repro.obs import trace as obs_trace

        obs_trace.get_tracer().export(args.trace)


if __name__ == "__main__":
    main()

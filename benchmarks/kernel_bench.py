"""Kernel micro-benchmarks: shape sweeps in interpret mode + coalescing stats.

Interpret-mode wall time is NOT TPU performance (the kernels target TPU; this
container is CPU) — the derived columns that matter are correctness vs the
oracle, the coalescing ratio (requests saved, paper §III-C), and the
latency-aware depth the scheduler solves (paper §III-D analogue).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_table, timed
from repro.core.descriptors import plan_gather
from repro.core.schedule import TileProfile, solve_depth, achieved_bandwidth
from repro.kernels.coro_gather.ops import coro_gather
from repro.kernels.coro_gather.ref import gather_ref
from repro.kernels.stream_copy.ops import stream_triad
from repro.kernels.stream_copy.ref import triad_ref


def gather_rows():
    rng = np.random.RandomState(0)
    out = []
    for n_rows, d, n_idx in ((512, 128, 256), (2048, 256, 512)):
        table = jnp.asarray(rng.randn(n_rows, d), jnp.float32)
        idx = jnp.asarray(rng.randint(0, n_rows, n_idx), jnp.int32)
        res, us = timed(coro_gather, table, idx, repeats=1)
        ok = bool(jnp.allclose(res, gather_ref(table, idx)))
        out.append(["coro_gather", f"{n_rows}x{d}/{n_idx}", round(us, 1), ok])
    return out


def coalesce_rows():
    rng = np.random.RandomState(1)
    out = []
    patterns = {
        "gups_random": rng.randint(0, 4096, 512),
        "stream_unit": np.arange(512),
        "hj_mixed": np.concatenate([np.arange(100, 300),
                                    rng.randint(0, 4096, 312)]),
    }
    for name, idx in patterns.items():
        plan = plan_gather(idx, span=8)
        out.append(["coalesce", name, plan.n_requests,
                    plan.requests_issued(), round(plan.coalescing_ratio(), 3)])
    return out


def schedule_rows():
    out = []
    for tag, tile_bytes, flops in (("gather_row", 8 * 2048 * 4, 64 * 8),
                                   ("kv_block", 2 * 128 * 8 * 128 * 2, 4 * 128 * 96 * 128),
                                   ("stream_tile", 2 * 128 * 512 * 4, 128 * 512)):
        p = TileProfile(tile_bytes=tile_bytes, flops_per_tile=float(flops))
        d = solve_depth(p)
        bw = achieved_bandwidth(p, d) / 1e9
        bw2 = achieved_bandwidth(p, 2) / 1e9
        out.append(["depth_solver", tag, d, round(bw, 1), round(bw2, 1)])
    return out


def triad_rows():
    rng = np.random.RandomState(2)
    b = jnp.asarray(rng.randn(1024, 64), jnp.float32)
    c = jnp.asarray(rng.randn(1024, 64), jnp.float32)
    res, us = timed(stream_triad, b, c, 2.5, repeats=1)
    ok = bool(jnp.allclose(res, triad_ref(b, c, 2.5), rtol=1e-5))
    return [["stream_triad", "1024x64", round(us, 1), ok]]


def table() -> str:
    s = csv_table(["kernel", "shape", "us_per_call", "allclose"],
                  gather_rows() + triad_rows())
    s += csv_table(["pass", "pattern", "requests", "issued", "ratio"],
                   coalesce_rows())
    s += csv_table(["pass", "tile", "depth", "GBps_at_depth", "GBps_at_2"],
                   schedule_rows())
    return s


if __name__ == "__main__":
    print(table())

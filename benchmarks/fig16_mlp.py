"""Fig. 16: memory-level parallelism (in-flight requests at the controller).

Paper: serial < 5 for latency-sensitive apps, prefetch-based < 20
(MSHR-capped), CoroAMU ~64 (SPM-backed, scalable with more coroutines).
"""
from __future__ import annotations

from repro.core import sim
from benchmarks.common import csv_table


def rows():
    out = []
    for name, b in sim.BENCHES.items():
        r = [name]
        for variant in ("serial", "coroamu-s", "coroamu-full"):
            m = sim.simulate(variant, b, latency_ns=800, n_coros=96).mlp
            r.append(round(m, 1))
        out.append(r)
    return out


def table() -> str:
    return csv_table(["bench", "serial", "prefetch", "coroamu"], rows())


if __name__ == "__main__":
    print(table())

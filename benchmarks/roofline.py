"""Roofline table: reads the dry-run artifacts (reports/dryrun/*.json).

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS (useful-compute ratio), and bytes/device.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_table

DEFAULT_DIR = Path("reports/dryrun")


def load(report_dir=DEFAULT_DIR):
    recs = []
    for p in sorted(Path(report_dir).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def rows(report_dir=DEFAULT_DIR):
    out = []
    for r in load(report_dir):
        if r.get("status") == "skipped":
            out.append([r["arch"], r["shape"], r["mesh"], "SKIP", "", "", "", "", "", ""])
            continue
        if r.get("status") != "ok":
            out.append([r["arch"], r["shape"], r["mesh"], "ERROR", "", "", "", "", "", ""])
            continue
        t = r["terms"]
        out.append([
            r["arch"], r["shape"], r["mesh"], r["dominant"].replace("_s", ""),
            f"{t['compute_s']:.3e}", f"{t['memory_s']:.3e}",
            f"{t['collective_s']:.3e}",
            round(r["useful_flops_ratio"], 3),
            round(r["memory"].get("argument_size_in_bytes", 0) / 2**30, 2),
            round(r["memory"].get("temp_size_in_bytes", 0) / 2**30, 2),
        ])
    return out


def table(report_dir=DEFAULT_DIR) -> str:
    return csv_table(
        ["arch", "shape", "mesh", "dominant", "compute_s", "memory_s",
         "collective_s", "useful_ratio", "args_GiB_dev", "temp_GiB_dev"],
        rows(report_dir))


if __name__ == "__main__":
    print(table())

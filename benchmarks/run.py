"""Benchmark harness: one table per paper figure + kernel bench + roofline.

Prints ``name,us_per_call,derived`` CSV summary lines followed by each full
table. Figure tables come from the calibrated performance model
(repro.core.sim — see DESIGN.md §2.2); the roofline table reads the
multi-pod dry-run artifacts if present.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import (  # noqa: E402
    fig11_compiler,
    fig12_latency,
    fig13_instructions,
    fig14_breakdown,
    fig15_optimizations,
    fig16_mlp,
    kernel_bench,
    roofline,
)
from repro.core import sim  # noqa: E402


def main() -> None:
    sections = [
        ("fig11_compiler_x86", fig11_compiler.table),
        ("fig12_latency_speedup", fig12_latency.table),
        ("fig13_instruction_expansion", fig13_instructions.table),
        ("fig14_cycle_breakdown", fig14_breakdown.table),
        ("fig15_compiler_opts", fig15_optimizations.table),
        ("fig16_mlp", fig16_mlp.table),
        ("kernel_bench", kernel_bench.table),
        ("roofline", roofline.table),
    ]
    print("name,us_per_call,derived")
    bodies = []
    for name, fn in sections:
        t0 = time.perf_counter()
        try:
            body = fn()
            derived = f"rows={body.count(chr(10)) - 1}"
        except Exception as e:  # keep the harness running
            body = f"ERROR: {e!r}\n"
            derived = "error"
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{derived}")
        bodies.append((name, body))

    # headline reproduction summary
    f200 = sim.average_speedup("coroamu-full", latency_ns=200)
    f800 = sim.average_speedup("coroamu-full", latency_ns=800)
    g = sim.BENCHES["GUPS"]
    print(f"headline,0,full@200={f200:.2f}x(paper3.39) full@800={f800:.2f}x(paper4.87) "
          f"GUPS@800={sim.speedup('coroamu-full', g, latency_ns=800):.1f}x(paper59.8)")

    for name, body in bodies:
        print(f"\n== {name} ==")
        print(body, end="")


if __name__ == "__main__":
    main()

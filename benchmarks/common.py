"""Shared benchmark-table helpers: every figure emits rows of CSV."""
from __future__ import annotations

import io
import time
from typing import Iterable, List, Sequence


def csv_table(header: Sequence[str], rows: Iterable[Sequence]) -> str:
    buf = io.StringIO()
    buf.write(",".join(map(str, header)) + "\n")
    for r in rows:
        buf.write(",".join(
            f"{x:.4g}" if isinstance(x, float) else str(x) for x in r) + "\n")
    return buf.getvalue()


def timed(fn, *args, repeats: int = 3, **kw):
    """(result, us_per_call) for the kernel micro-benches."""
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6

"""Fig. 13: dynamic instruction expansion vs serial (control cost).

Paper: 6.70x (CoroAMU-S) -> 5.98x (-D, SPM removes software queues) ->
3.91x (-Full, metadata offloaded into memory ops + bafin).
"""
from __future__ import annotations

from repro.core import sim
from benchmarks.common import csv_table


def rows():
    out = []
    for variant in ("coroutine", "coroamu-s", "coroamu-d", "coroamu-full"):
        # per-bench switch counts show WHERE the expansion goes
        per = []
        for b in sim.BENCHES.values():
            r = sim.simulate(variant, b, latency_ns=100, n_coros=96)
            sw = b.accesses
            if variant == "coroamu-full":
                sw = b.accesses * max(1 - (b.coalesce_spatial + b.coalesce_indep), 0.15)
            per.append(round(sw, 2))
        out.append([variant, sim.EXPANSION[variant], *per])
    return out


def table() -> str:
    return csv_table(["variant", "instr_expansion", *(f"{n}_switches" for n in sim.BENCHES)], rows())


if __name__ == "__main__":
    print(table())

"""Fig. 12: full-system speedup vs far-memory latency on NH-G.

Paper: CoroAMU-Full averages 3.39x @200ns and 4.87x @800ns over serial
(up to 29.0x / 59.8x on GUPS). CoroAMU-S is labeled at its best coroutine
count; -D/-Full run 96 coroutines.

Each row also reports the pipeline depth our TPU substrate would solve for
that latency (`schedule.solve_depth` on the GUPS-like row-gather tile) —
the §III-D point in one column: the chosen depth tracks latency instead of
being tuned for one value.
"""
from __future__ import annotations

from repro.core import autotune, sim
from repro.core.schedule import solve_depth
from benchmarks.common import csv_table

LATENCIES = (100, 200, 400, 800)

# the GUPS analogue on TPU: 8 random rows of a [*, 128] f32 table per tile
GATHER_PROFILE = autotune.profile_row_gather(8, 128, 4)


def rows():
    out = []
    for lat in LATENCIES:
        depth = solve_depth(GATHER_PROFILE, latency_s=lat * 1e-9)
        for variant in ("coroamu-s", "coroamu-d", "coroamu-full"):
            per = {}
            for name, b in sim.BENCHES.items():
                n = (sim.best_coros(variant, b, latency_ns=lat)
                     if variant == "coroamu-s" else 96)
                per[name] = sim.speedup(variant, b, latency_ns=lat, n_coros=n)
            out.append([lat, variant,
                        *(round(per[n], 2) for n in sim.BENCHES),
                        round(sim.geomean(list(per.values())), 2),
                        depth])
    return out


def table() -> str:
    return csv_table(
        ["latency_ns", "variant", *sim.BENCHES, "geomean", "tpu_depth"],
        rows())


if __name__ == "__main__":
    print(table())

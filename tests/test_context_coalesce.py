"""Property tests: coalescing planner and context classifier (paper §III-B/C).

The sweeps run as seeded `parametrize` cases so the suite has no hard
hypothesis dependency; one broader fuzz test uses hypothesis when it is
installed (pytest.importorskip) — the only place it adds coverage beyond
the seeded grid.
"""
import numpy as np
import pytest

from repro.core.context import VarClass, VarSpec, classify, context_bytes, max_depth
from repro.core.descriptors import apply_plan_reference, dedup_rmw, plan_gather


def _random_idx(seed: int, size: int, hi: int = 128) -> np.ndarray:
    r = np.random.RandomState(seed)
    # mix runs (coalescable) with random points, like real gather streams
    run_len = r.randint(0, max(size, 1) + 1)
    start = r.randint(0, hi - max(run_len, 1))
    run = np.arange(start, start + run_len)
    rand = r.randint(0, hi, size - run_len if size > run_len else 0)
    idx = np.concatenate([run, rand])[:size]
    return np.asarray(idx, np.int64)


@pytest.mark.parametrize("span", [2, 4, 8, 16])
@pytest.mark.parametrize("seed,size", [(0, 0), (1, 1), (2, 13), (3, 50),
                                       (4, 128), (5, 200)])
def test_plan_gather_is_exact_permutation(seed, size, span):
    """Every request appears exactly once, in the right output slot."""
    idx = _random_idx(seed, size)
    table = np.arange(128 * 4).reshape(128, 4).astype(np.float32)
    plan = plan_gather(idx, span=span)
    out = apply_plan_reference(plan, table)
    np.testing.assert_array_equal(out, table[idx] if len(idx) else out)
    assert plan.requests_issued() <= max(len(idx), 0) or len(idx) == 0


@pytest.mark.parametrize("span", [4, 8])
@pytest.mark.parametrize("run_len", [1, 3, 4, 7, 8, 9, 15, 16, 33, 64])
def test_plan_gather_coalesces_runs(run_len, span):
    idx = np.arange(run_len)
    plan = plan_gather(idx, span=span)
    assert plan.n_spans == run_len // span
    assert plan.n_singles == run_len % span


@pytest.mark.parametrize("seed,size", [(0, 1), (1, 7), (2, 23), (3, 60),
                                       (4, 41)])
def test_dedup_rmw_preserves_scatter_sum(seed, size):
    idx = np.asarray(np.random.RandomState(seed).randint(0, 32, size), np.int64)
    upd = np.random.RandomState(0).randn(len(idx), 3)
    uniq, summed = dedup_rmw(idx, upd)
    assert len(np.unique(uniq)) == len(uniq)
    direct = np.zeros((32, 3))
    np.add.at(direct, idx, upd)
    via = np.zeros((32, 3))
    via[uniq] += summed
    np.testing.assert_allclose(direct, via, atol=1e-12)


# ------------------------------------------------------------ context rules


def test_classification_matches_paper_rules():
    assert classify(VarSpec("ro", 8, read_only=True)) is VarClass.SHARED
    assert classify(VarSpec("priv", 8)) is VarClass.PRIVATE
    assert classify(VarSpec("acc", 8, carries_dependence=True,
                            commutative=True)) is VarClass.SHARED
    assert classify(VarSpec("seq", 8, carries_dependence=True)) is VarClass.SEQUENTIAL
    assert classify(VarSpec("hint", 8, hint=VarClass.SHARED)) is VarClass.SHARED


def _random_specs(seed: int, max_specs: int = 8, max_bytes: int = 4096):
    r = np.random.RandomState(seed)
    return [
        VarSpec(name=f"v{i}", nbytes=int(r.randint(1, max_bytes + 1)),
                read_only=bool(r.randint(2)),
                carries_dependence=bool(r.randint(2)),
                commutative=bool(r.randint(2)))
        for i in range(r.randint(1, max_specs + 1))
    ]


@pytest.mark.parametrize("depth", [1, 2, 37, 512])
@pytest.mark.parametrize("seed", range(10))
def test_optimized_context_never_larger(depth, seed):
    specs = _random_specs(seed)
    opt = context_bytes(specs, depth)
    base = context_bytes(specs, depth, baseline=True)
    assert opt <= base
    # and therefore the reachable depth never shrinks
    budget = base + 1
    assert max_depth(specs, budget) >= max_depth(specs, budget, baseline=True)


@pytest.mark.parametrize("budget", [0, 1, 100, 4096, 1 << 20])
@pytest.mark.parametrize("seed", range(5))
def test_max_depth_fits_budget(budget, seed):
    specs = _random_specs(seed, max_specs=5, max_bytes=1024)
    d = max_depth(specs, budget)
    if d > 0:
        assert context_bytes(specs, d) <= budget


# ------------------------------------- optional hypothesis fuzz (extra path)


def test_plan_gather_permutation_fuzz_hypothesis():
    """Broader fuzz of the planner when hypothesis is available."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(idx=st.lists(st.integers(0, 127), min_size=0, max_size=200),
           span=st.sampled_from([2, 4, 8, 16]))
    def prop(idx, span):
        idx = np.asarray(idx, np.int64)
        table = np.arange(128 * 4).reshape(128, 4).astype(np.float32)
        plan = plan_gather(idx, span=span)
        out = apply_plan_reference(plan, table)
        np.testing.assert_array_equal(out, table[idx] if len(idx) else out)

    prop()

"""Property tests: coalescing planner and context classifier (paper §III-B/C)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.context import VarClass, VarSpec, classify, context_bytes, max_depth
from repro.core.descriptors import apply_plan_reference, dedup_rmw, plan_gather


@settings(max_examples=60, deadline=None)
@given(idx=st.lists(st.integers(0, 127), min_size=0, max_size=200),
       span=st.sampled_from([2, 4, 8, 16]))
def test_plan_gather_is_exact_permutation(idx, span):
    """Every request appears exactly once, in the right output slot."""
    idx = np.asarray(idx, np.int64)
    table = np.arange(128 * 4).reshape(128, 4).astype(np.float32)
    plan = plan_gather(idx, span=span)
    out = apply_plan_reference(plan, table)
    np.testing.assert_array_equal(out, table[idx] if len(idx) else out)
    assert plan.requests_issued() <= max(len(idx), 0) or len(idx) == 0


@settings(max_examples=30, deadline=None)
@given(run_len=st.integers(1, 64), span=st.sampled_from([4, 8]))
def test_plan_gather_coalesces_runs(run_len, span):
    idx = np.arange(run_len)
    plan = plan_gather(idx, span=span)
    assert plan.n_spans == run_len // span
    assert plan.n_singles == run_len % span


@settings(max_examples=40, deadline=None)
@given(idx=st.lists(st.integers(0, 31), min_size=1, max_size=60))
def test_dedup_rmw_preserves_scatter_sum(idx):
    idx = np.asarray(idx, np.int64)
    upd = np.random.RandomState(0).randn(len(idx), 3)
    uniq, summed = dedup_rmw(idx, upd)
    assert len(np.unique(uniq)) == len(uniq)
    direct = np.zeros((32, 3))
    np.add.at(direct, idx, upd)
    via = np.zeros((32, 3))
    via[uniq] += summed
    np.testing.assert_allclose(direct, via, atol=1e-12)


# ------------------------------------------------------------ context rules


def test_classification_matches_paper_rules():
    assert classify(VarSpec("ro", 8, read_only=True)) is VarClass.SHARED
    assert classify(VarSpec("priv", 8)) is VarClass.PRIVATE
    assert classify(VarSpec("acc", 8, carries_dependence=True,
                            commutative=True)) is VarClass.SHARED
    assert classify(VarSpec("seq", 8, carries_dependence=True)) is VarClass.SEQUENTIAL
    assert classify(VarSpec("hint", 8, hint=VarClass.SHARED)) is VarClass.SHARED


@settings(max_examples=40, deadline=None)
@given(depth=st.integers(1, 512),
       specs=st.lists(
           st.builds(VarSpec,
                     name=st.text(min_size=1, max_size=4),
                     nbytes=st.integers(1, 4096),
                     read_only=st.booleans(),
                     carries_dependence=st.booleans(),
                     commutative=st.booleans()),
           min_size=1, max_size=8))
def test_optimized_context_never_larger(depth, specs):
    opt = context_bytes(specs, depth)
    base = context_bytes(specs, depth, baseline=True)
    assert opt <= base
    # and therefore the reachable depth never shrinks
    budget = base + 1
    assert max_depth(specs, budget) >= max_depth(specs, budget, baseline=True)


@settings(max_examples=30, deadline=None)
@given(budget=st.integers(0, 1 << 20),
       specs=st.lists(
           st.builds(VarSpec, name=st.just("v"), nbytes=st.integers(1, 1024)),
           min_size=1, max_size=5))
def test_max_depth_fits_budget(budget, specs):
    d = max_depth(specs, budget)
    if d > 0:
        assert context_bytes(specs, d) <= budget

"""Autotuned pipeline depth: solver properties + kernel entry-point wiring.

Covers the ISSUE-1/ISSUE-2 acceptance criteria:
  * the solved depth hides the modelled latency (hiding condition);
  * the VMEM budget caps it, with a floor of 2;
  * every kernel family's ``depth=None`` path chooses exactly
    `autotune.choose_depth` of that family's declared `CoroSpec`
    (profile + classified context vars);
  * gather/scatter outputs with autotuned depth match the references
    bit-exactly;
  * the run-time feedback path (`record_transfer` -> `adaptive_depth`)
    raises the depth when observed latency exceeds the model.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.schedule import (
    HBM_LATENCY_S,
    REQUEST_SLOTS,
    TileProfile,
    solve_depth,
    tile_compute_s,
    tile_transfer_s,
)
from repro.kernels.coro_gather.coro_gather import row_gather_spec
from repro.kernels.coro_gather.ops import coro_gather
from repro.kernels.coro_gather.ref import gather_ref
from repro.kernels.coro_scatter_add.coro_scatter_add import scatter_add_spec
from repro.kernels.coro_scatter_add.ops import coro_scatter_add
from repro.kernels.coro_scatter_add.ref import scatter_add_ref
from repro.kernels.decode_attention.decode_attention import decode_spec
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.moe_gmm.moe_gmm import gmm_spec
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ssd_scan import ssd_spec
from repro.kernels.stream_copy.ops import stream_triad
from repro.kernels.stream_copy.stream_copy import triad_spec


def _spec_depth(spec, n_tiles):
    """The depth a ``depth=None`` entry point should have recorded: the
    solver's answer clamped to the call's tile count (`last_choice` reports
    the depth actually run, never an unallocatable one)."""
    return min(autotune.choose_depth(spec.profile(), vars=spec.all_vars()),
               n_tiles)


@pytest.fixture(autouse=True)
def _clean_feedback():
    autotune.clear_samples()
    yield
    autotune.clear_samples()


# ----------------------------------------------------------- solver shape


@pytest.mark.parametrize("profile", [
    TileProfile(tile_bytes=64 * 1024, flops_per_tile=2e6),
    TileProfile(tile_bytes=2 * 1024, flops_per_tile=512.0),
    TileProfile(tile_bytes=512 * 1024, flops_per_tile=1e5),
])
def test_solved_depth_covers_latency(profile):
    # the hiding condition holds unless a capacity cap (SPM request slots /
    # VMEM) binds first — then the solver returns the cap itself
    d = solve_depth(profile)
    service = max(tile_compute_s(profile), tile_transfer_s(profile))
    covered = (d - 1) * service >= HBM_LATENCY_S + tile_transfer_s(profile)
    assert covered or d == REQUEST_SLOTS


def test_slot_limit_caps_depth():
    # near-zero compute, tiny tiles: uncapped MLP would be in the hundreds
    p = TileProfile(tile_bytes=512, flops_per_tile=8.0)
    assert solve_depth(p) == REQUEST_SLOTS
    assert solve_depth(p, slot_limit=8) == 8


def test_depth_respects_vmem_cap():
    p = TileProfile(tile_bytes=8 * 1024 * 1024, flops_per_tile=1e3,
                    private_bytes=8 * 1024 * 1024)
    budget = 64 * 1024 * 1024  # 64MB / 16MB-per-slot -> cap 4
    assert solve_depth(p, vmem_budget=budget) <= 4
    assert autotune.choose_depth(p, vmem_budget=budget) <= 4


def test_depth_floor_is_two():
    # enormous compute per tile: latency is trivially hidden, floor applies
    p = TileProfile(tile_bytes=1024, flops_per_tile=1e12)
    assert solve_depth(p) == 2
    assert autotune.choose_depth(p) == 2


# ---------------------------------------- entry points choose solve_depth


def test_every_kernel_entry_point_solves_its_spec(rng):
    """depth=None == choose_depth(spec.profile(), vars=spec.all_vars()) for
    all six families — the entry points consume the declared CoroSpec."""
    f32 = jnp.float32

    table = jnp.asarray(rng.randn(128, 64), jnp.float32)
    coro_gather(table, jnp.asarray(rng.randint(0, 128, 48), jnp.int32))
    assert autotune.last_choice("row_gather") == _spec_depth(
        row_gather_spec(8, 64, f32), n_tiles=48 // 8)

    coro_scatter_add(table, np.arange(16, dtype=np.int32),
                     jnp.asarray(rng.randn(16, 64), jnp.float32))
    assert autotune.last_choice("scatter_add") == _spec_depth(
        scatter_add_spec(8, 64, f32), n_tiles=16 // 8)

    q = jnp.asarray(rng.randn(1, 4, 16), jnp.float32)
    kv = jnp.asarray(rng.randn(1, 128, 2, 16), jnp.float32)
    decode_attention(q, kv, kv, 100, blk=32)
    assert autotune.last_choice("flash_decode") == _spec_depth(
        decode_spec(32, 2, 2, 16, f32), n_tiles=128 // 32)

    t = jnp.asarray(rng.randn(2, 8, 16), jnp.float32)
    w = jnp.asarray(rng.randn(2, 16, 256), jnp.float32)
    moe_gmm(t, w, f_tile=128)
    assert autotune.last_choice("moe_gmm") == _spec_depth(
        gmm_spec(8, 16, 128, f32, f_total=256), n_tiles=256 // 128)

    x = jnp.asarray(rng.randn(1, 64, 2, 8), jnp.float32)
    dt = jnp.asarray(rng.rand(1, 64, 2) * 0.5 + 0.1, jnp.float32)
    A = jnp.asarray(-np.exp(rng.randn(2) * 0.3), jnp.float32)
    B = jnp.asarray(rng.randn(1, 64, 16), jnp.float32)
    ssd(x, dt, A, B, B, chunk=16)
    assert autotune.last_choice("ssd_scan") == _spec_depth(
        ssd_spec(16, 2, 8, 16, f32, seq_len=64), n_tiles=64 // 16)

    b = jnp.asarray(rng.randn(256, 32), jnp.float32)
    stream_triad(b, b, 2.0, rows=64)
    assert autotune.last_choice("stream_triad") == _spec_depth(
        triad_spec(64, 32, f32), n_tiles=256 // 64)


def test_gather_autotuned_depth_matches_ref_bit_exact(rng):
    table = jnp.asarray(rng.randn(256, 32) * 10, jnp.float32)
    idx = jnp.asarray(rng.randint(0, 256, 77), jnp.int32)
    out = coro_gather(table, idx, depth=None)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(gather_ref(table, idx)))


def test_scatter_autotuned_depth_matches_ref_bit_exact(rng):
    # f32 adds in dedup + kernel follow the same order as the oracle's
    # np.add.at over unique rows -> bit-exact
    table = jnp.zeros((64, 16), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, 40), jnp.int32)
    upd = jnp.asarray(np.ones((40, 16), np.float32))
    out = coro_scatter_add(table, idx, upd, depth=None)
    ref = scatter_add_ref(table, idx, upd)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ------------------------------------------------------- feedback path


def test_recorded_latency_raises_depth():
    p = TileProfile(tile_bytes=64 * 1024, flops_per_tile=2e6)
    base = autotune.choose_depth(p, kernel="probe")
    for _ in range(20):
        autotune.record_transfer("probe", 10e-6)  # far slower than modelled
    adapted = autotune.choose_depth(p, kernel="probe")
    assert adapted > base
    assert autotune.last_choice("probe") == adapted
    autotune.clear_samples("probe")
    assert autotune.choose_depth(p, kernel="probe") == base

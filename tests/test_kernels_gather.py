"""coro_gather kernel: allclose vs oracle across shapes/dtypes (+ coalescing).

Property tests run as seeded `parametrize` sweeps (no hard hypothesis dep).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.coro_gather.ops import coalesced_gather, coro_gather
from repro.kernels.coro_gather.ref import gather_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("n_rows,d,n_idx", [(64, 128, 32), (256, 256, 61), (128, 8, 16)])
def test_row_gather_matches_ref(rng, dtype, n_rows, d, n_idx):
    table = jnp.asarray(rng.randn(n_rows, d) * 10, dtype)
    idx = jnp.asarray(rng.randint(0, n_rows, n_idx), jnp.int32)
    out = coro_gather(table, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gather_ref(table, idx)))


@pytest.mark.parametrize("depth", [1, 2, 3, 8])
@pytest.mark.parametrize("rows_per_tile", [1, 4, 8])
def test_row_gather_depth_tile_sweep(rng, depth, rows_per_tile):
    table = jnp.asarray(rng.randn(128, 64), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 128, 48), jnp.int32)
    out = coro_gather(table, idx, depth=depth, rows_per_tile=rows_per_tile)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gather_ref(table, idx)))


@pytest.mark.parametrize("span", [2, 4, 8])
@pytest.mark.parametrize("seed,n_idx", [(0, 1), (1, 7), (2, 33), (3, 80), (4, 52)])
def test_coalesced_gather_matches_direct(seed, n_idx, span):
    r = np.random.RandomState(seed)
    table = jnp.asarray(np.arange(64 * 16, dtype=np.float32).reshape(64, 16))
    # mix of runs and random points so both sub-pipelines are exercised
    run = np.arange(r.randint(0, 32), dtype=np.int64)
    idx = np.concatenate([run, r.randint(0, 64, n_idx)])[:max(n_idx, 1)]
    idx = np.asarray(idx, np.int32)
    out, plan = coalesced_gather(table, idx, span=span)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(table)[idx])
    assert plan.requests_issued() <= plan.n_requests or plan.n_requests == 0


def test_coalescing_saves_requests_on_streams():
    table = jnp.zeros((512, 8), jnp.float32)
    out, plan = coalesced_gather(table, np.arange(256), span=8)
    assert plan.n_spans == 32 and plan.n_singles == 0
    assert plan.coalescing_ratio() == 32 / 256

"""Substrate: data determinism, checkpoint/restart, compression, FT, schedule.

Property tests run as seeded `parametrize` sweeps so the suite collects
without optional deps (hypothesis lives behind importorskip in
test_context_coalesce.py only).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.checkpoint import latest_step, restore, save
from repro.core.schedule import (
    TileProfile,
    achieved_bandwidth,
    adaptive_depth,
    solve_depth,
    static_prefetch_depth,
)
from repro.data.pipeline import DataConfig, MarkovTask, PrefetchIterator
from repro.optim.compression import dequantize_int8, ef_compress, init_error_state, quantize_int8
from repro.core.guard import KernelResourceError
from repro.runtime.fault_tolerance import (
    StragglerMonitor,
    run_with_restarts,
)


# ---------------------------------------------------------------- data


def test_data_is_deterministic_and_step_dependent():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=4)
    task = MarkovTask(cfg)
    a = task.batch_for_step(7)
    b = task.batch_for_step(7)
    c = task.batch_for_step(8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # targets are the next-token shift of the same stream
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])


def test_data_shards_partition_batch():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=8, num_shards=2, shard=0)
    t0 = MarkovTask(cfg).batch_for_step(3)
    t1 = MarkovTask(DataConfig(vocab=64, seq_len=8, global_batch=8,
                               num_shards=2, shard=1)).batch_for_step(3)
    assert t0["tokens"].shape == (4, 8)
    assert not np.array_equal(t0["tokens"], t1["tokens"])


def test_prefetch_iterator_yields_in_order():
    task = MarkovTask(DataConfig(vocab=32, seq_len=8, global_batch=2))
    it = PrefetchIterator(task, start_step=5)
    steps = [next(it)[0] for _ in range(3)]
    it.close()
    assert steps == [5, 6, 7]


def test_markov_entropy_is_a_floor():
    task = MarkovTask(DataConfig(vocab=64, seq_len=8, global_batch=2))
    assert 0.0 < task.entropy() < math.log(64)


# ------------------------------------------------------------ checkpoints


def test_checkpoint_roundtrip_and_gc(tmp_path):
    state = {"step": jnp.asarray(3), "w": jnp.arange(6.0).reshape(2, 3)}
    for s in (1, 2, 3):
        save(state, tmp_path, s, keep=2)
    assert latest_step(tmp_path) == 3
    assert not (tmp_path / "step_00000001").exists()  # gc'd
    out = restore(tmp_path, state)
    np.testing.assert_array_equal(out["w"], np.asarray(state["w"]))


def test_checkpoint_restore_is_elastic_template_based(tmp_path):
    state = {"a": jnp.ones((4, 4)), "b": jnp.zeros((2,))}
    save(state, tmp_path, 10)
    # a "new cluster" provides only the template tree; arrays come from disk
    template = {"a": jnp.zeros((4, 4)), "b": jnp.ones((2,))}
    out = restore(tmp_path, template)
    np.testing.assert_array_equal(out["a"], np.ones((4, 4)))


# ------------------------------------------------------------ compression


@pytest.mark.parametrize("scale", [1e-3, 3e-2, 0.5, 1.0, 37.5, 4e2, 1e3])
def test_quantize_int8_bounded_error(scale):
    x = jnp.asarray(np.random.RandomState(0).randn(64) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_removes_bias():
    rng = np.random.RandomState(1)
    g = {"w": jnp.asarray(rng.randn(128) * 1e-2, jnp.float32)}
    err = init_error_state(g)
    acc_comp = np.zeros(128)
    steps = 200
    for _ in range(steps):
        dq, err = ef_compress(g, err)
        acc_comp += np.asarray(dq["w"])
    acc_true = np.asarray(g["w"]) * steps
    # long-run accumulated update converges to the true sum (bias -> 0)
    assert np.abs(acc_comp - acc_true).max() / np.abs(acc_true).max() < 0.02


# --------------------------------------------------------- fault tolerance


def test_run_with_restarts_recovers():
    calls = {"n": 0, "restores": 0}

    def loop():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("node lost")

    rep = run_with_restarts(loop, restore_fn=lambda: calls.__setitem__(
        "restores", calls["restores"] + 1), max_restarts=5)
    assert rep.completed and rep.restarts == 2 and calls["restores"] == 2


def test_run_with_restarts_gives_up():
    rep = run_with_restarts(lambda: (_ for _ in ()).throw(RuntimeError("x")),
                            restore_fn=lambda: None, max_restarts=2)
    assert not rep.completed and len(rep.failures) == 3


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=16, threshold=2.0)
    for _ in range(10):
        mon.record(0.1)
    assert mon.record(0.5) is True
    assert mon.record(0.1) is False


def test_run_with_restarts_records_substrate_context():
    """A SubstrateError escaping a step (strict mode / no twin) is retriable
    AND its kernel context lands in the report — post-mortems can tell a
    dying node from a bad kernel config (DESIGN.md §2.7)."""
    calls = {"n": 0}

    def loop():
        calls["n"] += 1
        if calls["n"] < 2:
            raise KernelResourceError("vmem exhausted", kernel="row_gather",
                                      machine="v5e", depth=8)

    rep = run_with_restarts(loop, restore_fn=lambda: None, max_restarts=3)
    assert rep.completed and rep.restarts == 1
    assert "KernelResourceError[kernel=row_gather machine=v5e depth=8]" \
        in rep.failures[0]


# -------------------------------------------------------------- schedule


def test_solve_depth_hides_latency():
    p = TileProfile(tile_bytes=64 * 1024, flops_per_tile=2e6)
    d = solve_depth(p, latency_s=700e-9)
    # at the solved depth the pipeline sustains ~compute-bound throughput
    bw = achieved_bandwidth(p, d, latency_s=700e-9)
    bw_ideal = p.tile_bytes / (p.flops_per_tile / 197e12)
    assert bw >= 0.9 * min(bw_ideal, 819e9)


@pytest.mark.parametrize(
    "lat", [100e-9, 175e-9, 350e-9, 700e-9, 1.3e-6, 2.5e-6, 5e-6])
def test_depth_monotone_in_latency(lat):
    p = TileProfile(tile_bytes=32 * 1024, flops_per_tile=1e6)
    assert solve_depth(p, latency_s=2 * lat) >= solve_depth(p, latency_s=lat)


def test_adaptive_depth_uses_tail_latency():
    p = TileProfile(tile_bytes=32 * 1024, flops_per_tile=1e6)
    quiet = adaptive_depth(p, [200e-9] * 100)
    spiky = adaptive_depth(p, [200e-9] * 90 + [2e-6] * 10)
    assert spiky >= quiet


def test_static_prefetch_is_mshr_capped():
    p = TileProfile(tile_bytes=1024, flops_per_tile=1e3)
    assert static_prefetch_depth(p, latency_s=5e-6, mshr_limit=16) <= 16

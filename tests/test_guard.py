"""The guarded kernel substrate (ISSUE-10, DESIGN.md §2.7): taxonomy,
depth-backoff ladder, twin fallback, circuit breaker, config quarantine,
parity sentinels, strict mode — and the engine-level guarantee that a
kernel-site chaos schedule degrades answers never, throughput maybe.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import autotune, guard
from repro.core.guard import (
    KernelCompileError,
    KernelNumericsError,
    KernelParityError,
    KernelResourceError,
    SubstrateError,
)
from repro.kernels.coro_gather.ops import coro_gather
from repro.kernels.coro_gather.ref import gather_ref
from repro.models import build_model
from repro.serve import FaultInjector, PagedServingEngine, TERMINAL_STATES


@pytest.fixture
def twin_registry():
    """Register throwaway twins; unregister on teardown so fake names never
    leak into the process-wide registry."""
    import repro.kernels as kernels_pkg

    added = []

    def add(name, fn):
        kernels_pkg.register_twin(name, fn)
        added.append(name)

    yield add
    for name in added:
        kernels_pkg._TWINS.pop(name, None)


def _fake_spec(name):
    """Just enough spec surface for guarded_call: a name and no streams."""
    return types.SimpleNamespace(name=name, loads=(), stores=())


def _gather_operands(n_idx=64):
    rng = np.random.RandomState(0)
    table = jnp.asarray(rng.randn(256, 128), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 256, n_idx), jnp.int32)
    return table, idx


# ---------------------------------------------------------------- taxonomy


def test_taxonomy_carries_launch_context():
    e = KernelResourceError("vmem overcommit", kernel="row_gather",
                            machine="v5e", depth=8, tile=(8, 128))
    assert isinstance(e, SubstrateError) and isinstance(e, RuntimeError)
    assert (e.kernel, e.machine, e.depth, e.tile) == (
        "row_gather", "v5e", 8, (8, 128))
    msg = str(e)
    assert "kernel=row_gather" in msg and "depth=8" in msg


def test_taxonomy_defaults_machine_from_active_profile():
    e = KernelCompileError("boom", kernel="k")
    from repro.core.machine import get_machine
    assert e.machine == get_machine().name


def test_classification_resource_vs_compile():
    """A raw RuntimeError mentioning VMEM classifies as resource pressure;
    anything else as a compile/lowering failure — with the original as
    __cause__ (no twin registered, so the typed error surfaces)."""
    spec = _fake_spec("no_twin_classify_probe")

    def oom(_d):
        raise RuntimeError("RESOURCE_EXHAUSTED: scoped vmem request")

    with pytest.raises(KernelResourceError) as ei:
        guard.guarded_call(spec, (), oom, depth=1, n_tiles=1)
    assert isinstance(ei.value.__cause__, RuntimeError)

    def lowering(_d):
        raise ValueError("unsupported lowering")

    with pytest.raises(KernelCompileError):
        guard.guarded_call(spec, (), lowering, depth=1, n_tiles=1)


# ---------------------------------------------------------------- policing


def test_scan_output_flags_nonfinite_floats_only():
    assert guard.scan_output("k", jnp.ones((4,))) is None
    assert guard.scan_output("k", jnp.arange(4)) is None  # ints never flagged
    err = guard.scan_output("k", [jnp.ones(3), jnp.array([1.0, jnp.nan])],
                            depth=2)
    assert isinstance(err, KernelNumericsError) and err.depth == 2
    assert guard.stats()["numerics_faults"] == 1


def test_scan_output_skips_tracers():
    @jax.jit
    def f(x):
        assert guard.scan_output("k", x) is None  # tracer: nothing to police
        return x

    f(jnp.ones(3))
    assert guard.stats()["numerics_faults"] == 0


def test_check_injected_raises_typed_errors():
    inj = FaultInjector(0, rates={"kernel_oom": 1.0})
    with pytest.raises(KernelResourceError):
        guard.check_injected("paged_decode_round", inj, round=3)
    assert guard.stats()["injected_faults"] == 1


# ------------------------------------------------------------------ ladder


def test_compile_fault_walks_ladder_to_twin():
    """Every attempt fails like a Mosaic compile error: the ladder halves
    monotonically to depth 1, every failed depth is quarantined, and the
    registered jnp twin still produces the exact answer."""
    table, idx = _gather_operands()
    guard.set_injector(FaultInjector(0, rates={"kernel_compile": 1.0}))
    out = coro_gather(table, idx)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gather_ref(table, idx)))

    ladder = guard.last_ladder("row_gather")
    assert ladder and ladder[-1] == 1
    assert all(a > b for a, b in zip(ladder, ladder[1:]))  # strictly falling
    assert autotune.quarantined_depths("row_gather") == sorted(ladder)

    s = guard.stats()
    assert s["fallbacks"] == 1 and s["backoffs"] == len(ladder) - 1
    assert s["injected_faults"] == len(ladder)


def test_nan_injection_caught_by_scan_then_twin():
    """kernel_nan poisons every successful attempt's output; the always-on
    scan converts each to KernelNumericsError until the twin answers."""
    table, idx = _gather_operands()
    guard.set_injector(FaultInjector(0, rates={"kernel_nan": 1.0}))
    out = coro_gather(table, idx)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gather_ref(table, idx)))
    s = guard.stats()
    assert s["numerics_faults"] == len(guard.last_ladder("row_gather"))
    assert s["fallbacks"] == 1


def test_quarantine_steers_choose_depth():
    """choose_depth never re-proposes a quarantined (machine, kernel, depth):
    it halves below the poisoned configs instead."""
    prof = autotune.profile_row_gather(8, 512, 4)
    d = autotune.choose_depth(prof, kernel="quarantine_probe")
    assert d >= 2  # the ladder below needs a rung to descend
    while d > 1:
        autotune.quarantine_config("quarantine_probe", d)
        assert autotune.is_quarantined("quarantine_probe", d)
        nd = autotune.choose_depth(prof, kernel="quarantine_probe")
        assert nd < d and not autotune.is_quarantined("quarantine_probe", nd)
        d = nd
    assert autotune.quarantined_depths("quarantine_probe")
    autotune.clear_quarantine("quarantine_probe")
    assert not autotune.quarantined_depths("quarantine_probe")


# ------------------------------------------------------------------ parity


def test_parity_sentinel_catches_poisoned_kernel(twin_registry):
    """A kernel that silently computes the wrong answer is caught by the
    sentinel: the twin's output is substituted and the failure feeds the
    quarantine/breaker path exactly like a crash."""
    x = jnp.arange(8.0)
    twin_registry("parity_probe", lambda spec, v: v + 1.0)
    guard.set_parity("full")

    spec = _fake_spec("parity_probe")
    res = guard.guarded_call(spec, (x,), lambda d: x + 2.0,  # wrong answer
                             depth=1, n_tiles=1)
    assert res.path == "twin" and res.fallback
    np.testing.assert_allclose(np.asarray(res.out), np.asarray(x + 1.0))
    s = guard.stats()
    assert s["parity_checks"] == 1 and s["parity_mismatches"] == 1
    assert s["fallbacks"] == 1
    assert autotune.quarantined_depths("parity_probe") == [1]


def test_parity_strict_raises(twin_registry):
    x = jnp.arange(4.0)
    twin_registry("parity_strict_probe", lambda spec, v: v * 2.0)
    guard.set_parity("full")
    guard.set_strict(True)
    spec = _fake_spec("parity_strict_probe")
    with pytest.raises(KernelParityError):
        guard.guarded_call(spec, (x,), lambda d: v_wrong(x), depth=1,
                           n_tiles=1)


def v_wrong(x):
    return x * 3.0


def test_parity_sampled_is_deterministic_1_in_n(twin_registry):
    """sampled mode checks call 1, N+1, 2N+1, ... per (machine, kernel) —
    deterministic, not random."""
    x = jnp.ones(4)
    twin_registry("parity_sample_probe", lambda spec, v: v)
    guard.set_parity("sampled", every=3)
    spec = _fake_spec("parity_sample_probe")
    for _ in range(7):
        guard.guarded_call(spec, (x,), lambda d: x, depth=1, n_tiles=1)
    assert guard.stats()["parity_checks"] == 3  # calls 1, 4, 7


def test_parity_clean_kernel_passes_full_check():
    """The real row_gather kernel against its real twin: full parity on a
    clean call must record a check and no mismatch."""
    table, idx = _gather_operands(32)
    guard.set_parity("full")
    out = coro_gather(table, idx)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gather_ref(table, idx)))
    s = guard.stats()
    assert s["parity_checks"] >= 1 and s["parity_mismatches"] == 0
    assert s["clean_calls"] >= 1


# ----------------------------------------------------------------- breaker


def test_breaker_opens_routes_probes_and_closes(twin_registry):
    """closed -> open after BREAKER_THRESHOLD consecutive failures; while
    open, calls route to the twin WITHOUT attempting the kernel; after
    BREAKER_COOLDOWN_CALLS a half-open probe runs the kernel and, on
    success, re-closes."""
    twin_registry("breaker_probe", lambda spec: jnp.zeros(2))
    guard.set_parity("off")  # the sentinel would flag twin != attempt output
    spec = _fake_spec("breaker_probe")
    calls = {"n": 0, "fail": True}

    def attempt(_d):
        calls["n"] += 1
        if calls["fail"]:
            raise RuntimeError("persistent lowering bug")
        return jnp.ones(2)

    def one():
        return guard.guarded_call(spec, (), attempt, depth=1, n_tiles=1)

    for i in range(guard.BREAKER_THRESHOLD):
        assert one().path == "twin"
    assert guard.breaker_state("breaker_probe") == "open"
    assert calls["n"] == guard.BREAKER_THRESHOLD

    for _ in range(guard.BREAKER_COOLDOWN_CALLS - 1):
        res = one()
        assert res.path == "breaker" and res.fallback
    assert calls["n"] == guard.BREAKER_THRESHOLD  # kernel never attempted
    assert guard.stats()["breakers"] == {"breaker_probe": "open"}

    calls["fail"] = False  # the bug is "fixed"; cooldown over: probe
    res = one()
    assert res.path == "clean"
    assert calls["n"] == guard.BREAKER_THRESHOLD + 1
    assert guard.breaker_state("breaker_probe") == "closed"
    assert guard.stats()["breaker_trips"] == 1


def test_breaker_failed_probe_reopens(twin_registry):
    twin_registry("breaker_reopen_probe", lambda spec: jnp.zeros(1))
    spec = _fake_spec("breaker_reopen_probe")

    def attempt(_d):
        raise RuntimeError("still broken")

    def one():
        return guard.guarded_call(spec, (), attempt, depth=1, n_tiles=1)

    for _ in range(guard.BREAKER_THRESHOLD + guard.BREAKER_COOLDOWN_CALLS):
        one()
    # the last call was the half-open probe; it failed -> open again
    assert guard.breaker_state("breaker_reopen_probe") == "open"
    assert guard.stats()["breaker_trips"] == 2


# ------------------------------------------------------------------ strict


def test_strict_clean_path_records_zero_degradation():
    guard.set_strict(True)
    table, idx = _gather_operands(32)
    out = coro_gather(table, idx)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gather_ref(table, idx)))
    s = guard.stats()
    assert s["clean_calls"] >= 1
    assert s["backoffs"] == 0 and s["fallbacks"] == 0


def test_strict_surfaces_first_failure():
    guard.set_strict(True)
    guard.set_injector(FaultInjector(0, rates={"kernel_compile": 1.0}))
    table, idx = _gather_operands(32)
    with pytest.raises(KernelCompileError) as ei:
        coro_gather(table, idx)
    assert ei.value.kernel == "row_gather"
    assert len(guard.last_ladder("row_gather")) == 1  # no ladder walked


# ---------------------------------------------------------- engine + chaos


def _f32_cfg():
    return get_config("yi-6b").reduced().replace(dtype="float32",
                                                 param_dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    cfg = _f32_cfg()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_engine_kernel_chaos_terminal_and_replayable(tiny):
    """Kernel-site chaos (compile / oom / nan at the engine's guarded call
    sites) drains every request to a terminal state with ZERO parity
    mismatches — and replays bit-for-bit across two identical runs."""
    cfg, params = tiny
    rates = {"pool_exhausted": 0.05, "kernel_compile": 0.25,
             "kernel_oom": 0.2, "kernel_nan": 0.2}

    def run():
        rng = np.random.default_rng(11)
        inj = FaultInjector(9, rates=rates)
        eng = PagedServingEngine(cfg, params=params, block_size=4,
                                 num_blocks=12, faults=inj, max_in_flight=3)
        rids = [eng.submit(rng.integers(0, cfg.vocab,
                                        int(rng.integers(3, 9))),
                           max_new_tokens=2) for _ in range(6)]
        stats = eng.run()
        eng.pager.check_invariants(
            eng.prefix_cache.block_refs() if eng.prefix_cache else None)
        outcomes = [(eng.request(r).state, eng.request(r).finish_reason,
                     tuple(eng.request(r).generated)) for r in rids]
        return outcomes, inj.stats(), stats

    out1, inj1, stats1 = run()
    out2, inj2, _ = run()
    assert all(state in TERMINAL_STATES for state, _, _ in out1)
    assert out1 == out2 and inj1 == inj2
    kernel_hits = sum(inj1["by_site"].get(s, 0) for s in
                      ("kernel_compile", "kernel_oom", "kernel_nan"))
    assert kernel_hits > 0, inj1
    sub = guard.stats()
    assert sub["injected_faults"] > 0
    assert sub["parity_mismatches"] == 0
    assert stats1["substrate"]["parity_mismatches"] == 0  # engine stats view

"""Flash-decode kernel: position sweep, GQA/MQA ratios, block sizes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@pytest.mark.parametrize("kh,h", [(2, 8), (1, 4), (4, 4)])
@pytest.mark.parametrize("pos", [0, 63, 200, 255])
def test_decode_attention_matches_ref(rng, kh, h, pos):
    B, D, S = 2, 16, 256
    q = jnp.asarray(rng.randn(B, h, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, kh, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, kh, D), jnp.float32)
    out = decode_attention(q, k, v, pos, blk=64)
    ref = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("blk,depth", [(32, 2), (64, 4), (128, 8)])
def test_decode_attention_block_depth_sweep(rng, blk, depth):
    B, h, kh, D, S = 2, 4, 2, 16, 256
    q = jnp.asarray(rng.randn(B, h, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, kh, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, kh, D), jnp.float32)
    out = decode_attention(q, k, v, 170, blk=blk, depth=depth)
    ref = decode_attention_ref(q, k, v, 170)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_decode_attention_bf16(rng):
    B, h, kh, D, S = 1, 4, 2, 32, 128
    q = jnp.asarray(rng.randn(B, h, D), jnp.bfloat16)
    k = jnp.asarray(rng.randn(B, S, kh, D), jnp.bfloat16)
    v = jnp.asarray(rng.randn(B, S, kh, D), jnp.bfloat16)
    out = decode_attention(q, k, v, 100, blk=32)
    ref = decode_attention_ref(q, k, v, 100)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2)

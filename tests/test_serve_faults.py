"""Serving failure model (ISSUE-9): the partial-prefix pool-pressure crash
regression, deterministic fault injection, deadlines / cancel / shed,
quarantine + stall accounting, and pool-pressure fuzz on 1-4 block pools —
every submitted request must reach a terminal state with the pager
invariants intact, no matter what the pool or the injected chaos does."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    TERMINAL_STATES,
    ContinuousBatchingScheduler,
    FaultInjector,
    InjectedFault,
    KVPager,
    NULL_INJECTOR,
    PagedServingEngine,
    PoolExhausted,
    PrefixCache,
    Request,
    RequestState,
)

# --------------------------------------------------------- fault injector


def test_injector_is_deterministic_across_instances():
    a, b = FaultInjector(7), FaultInjector(7)
    seq_a = [a.fire("decode") for _ in range(300)]
    seq_b = [b.fire("decode") for _ in range(300)]
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)  # the default rate actually draws
    assert a.stats() == b.stats()


def test_injector_sites_are_independent_streams():
    """Draining one site must not perturb another's n-th decision."""
    rates = {"decode": 0.5, "prefill": 0.5}
    a = FaultInjector(3, rates=rates)
    b = FaultInjector(3, rates=rates)
    for _ in range(200):
        a.fire("decode")  # only a drains the decode stream
    seq_a = [a.fire("prefill") for _ in range(200)]
    seq_b = [b.fire("prefill") for _ in range(200)]
    assert seq_a == seq_b


def test_injector_rate_bounds_and_unknown_sites():
    inj = FaultInjector(0, rates={"decode": 1.0, "prefill": 0.0})
    assert all(inj.fire("decode") for _ in range(50))
    assert not any(inj.fire("prefill") for _ in range(50))
    assert inj.fire("latency") is False  # unlisted in rates: never fires
    assert inj.by_site == {"decode": 50}
    with pytest.raises(ValueError):
        FaultInjector(0, rates={"not_a_site": 0.5})


def test_injector_check_raises_and_max_faults_caps():
    inj = FaultInjector(0, rates={"decode": 1.0}, max_faults=2)
    with pytest.raises(InjectedFault):
        inj.check("decode")
    with pytest.raises(InjectedFault):
        inj.check("decode")
    inj.check("decode")  # budget spent: the site goes quiet
    assert inj.injected == 2 and inj.by_site == {"decode": 2}
    assert len(inj.log) == 2


def test_injector_latency_spike_magnitude_bounds():
    inj = FaultInjector(1, rates={"latency": 1.0}, latency_spike_s=1e-3)
    for _ in range(25):
        s = inj.latency_spike()
        assert 0.5e-3 <= s <= 1.5e-3
    quiet = FaultInjector(1, rates={"latency": 0.0})
    assert quiet.latency_spike() == 0.0


def test_null_injector_is_inert():
    assert NULL_INJECTOR.fire("decode") is False
    NULL_INJECTOR.check("decode")  # never raises
    assert NULL_INJECTOR.latency_spike() == 0.0
    assert NULL_INJECTOR.injected == 0
    assert NULL_INJECTOR.enabled is False
    assert NULL_INJECTOR.stats()["by_site"] == {}


# ------------------------------------------------------------------ pager


def test_pager_pop_token_rolls_back_reservation():
    pager = KVPager(num_blocks=4, block_size=4)
    pager.alloc(0, 4)  # exactly one full block
    pos = pager.append_token(0)  # reservation grows a second block
    assert pos == 4 and len(pager.block_table(0)) == 2
    pager.pop_token(0)  # the round raised: undo
    assert pager.length(0) == 4 and len(pager.block_table(0)) == 1
    pager.check_invariants()
    # mid-block pop leaves the table alone
    pager.append_token(0)
    pager.append_token(0)
    pager.pop_token(0)
    assert pager.length(0) == 5 and len(pager.block_table(0)) == 2
    pager.check_invariants()


def test_pager_pop_token_without_reservation_raises():
    pager = KVPager(num_blocks=2, block_size=4)
    pager.alloc(0, 1)
    pager.pop_token(0)  # down to zero tokens frees the page
    assert pager.length(0) == 0 and pager.free_blocks == 2
    with pytest.raises(ValueError):
        pager.pop_token(0)
    pager.check_invariants()


def test_pager_injected_exhaustion_rolls_back_partial_claim():
    """An injected PoolExhausted mid-alloc must leave no leak behind —
    neither half-popped fresh blocks nor prefix refcounts."""
    pager = KVPager(num_blocks=8, block_size=4)
    t0 = pager.alloc(0, 8)
    cached = t0[0]
    pager.share(cached)  # emulate the prefix cache keeping the page alive
    pager.free(0)
    assert pager.refcount(cached) == 1 and pager.free_blocks == 7
    pager.faults = FaultInjector(0, rates={"pool_exhausted": 1.0})
    with pytest.raises(PoolExhausted):
        pager.alloc(1, 12, prefix_blocks=[cached], prefix_len=4)
    pager.check_invariants({cached: 1})
    assert pager.refcount(cached) == 1  # the failed claim's ref rolled back
    assert pager.free_blocks == 7 and not pager.owns(1)


# ------------------------------------- the reproduced crash (satellite 1)


def _cache_partial_prefix(pager, cache, prompt):
    """Simulate request A: prefill `prompt`, cache its full blocks, finish."""
    pager.alloc(0, len(prompt))
    cache.insert(prompt, pager.block_table(0))
    pager.free(0)


def test_admit_reserves_cow_block_for_partial_prefix_match():
    """ISSUE-9 reproduced crash, scheduler-level: 2-block pool, one cached
    page, a prompt matching it mid-block. On main, `admit` claimed the last
    free block for the suffix and the first suffix write then had to fork
    the shared partial page with zero free blocks, zero evictable pages
    (the match is refcounted >= 2) and zero preemption victims —
    PoolExhausted escaped. Admission must reserve the fork's block (or give
    the match up), so the first write never raises."""
    pager = KVPager(num_blocks=2, block_size=4)
    cache = PrefixCache(pager)
    a = list(range(100, 105))  # 5 tokens = 2 blocks; the first gets cached
    _cache_partial_prefix(pager, cache, a)
    assert pager.free_blocks == 1 and len(cache) == 1

    sched = ContinuousBatchingScheduler(
        pager, 2, reclaim=lambda n, p: len(cache.evict(n, p)))
    b = Request(rid=1, prompt=a[:2] + [7, 8, 9, 10, 11], max_new_tokens=1)
    sched.submit(b)
    assert sched.admit(match=cache.match) == [b]
    sched.make_writable(b, b.prefill_pos)  # the first-write fork
    pager.check_invariants(cache.block_refs())


def test_admit_keeps_partial_match_when_pool_has_the_spare_block():
    """Same shape, 3-block pool: sharing must survive — the reserve comes
    from the pool, not from giving the match up."""
    pager = KVPager(num_blocks=3, block_size=4)
    cache = PrefixCache(pager)
    a = list(range(100, 105))
    _cache_partial_prefix(pager, cache, a)
    assert pager.free_blocks == 2

    sched = ContinuousBatchingScheduler(
        pager, 2, reclaim=lambda n, p: len(cache.evict(n, p)))
    b = Request(rid=1, prompt=a[:2] + [7, 8, 9, 10, 11], max_new_tokens=1)
    sched.submit(b)
    assert sched.admit(match=cache.match) == [b]
    assert b.matched_len == 2  # the partial-block hit was kept
    copy = sched.make_writable(b, b.prefill_pos)
    assert copy is not None  # the fork spent the reserved block
    assert len(cache) == 1  # nothing was sacrificed
    pager.check_invariants(cache.block_refs())


# ------------------------------------------------------------- tiny engine


def _f32_cfg():
    return get_config("yi-6b").reduced().replace(dtype="float32",
                                                 param_dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    cfg = _f32_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _eng(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 16)
    return PagedServingEngine(cfg, params=params, **kw)


def _drive_checked(eng, max_rounds=500):
    """step_round until drained, asserting pager invariants EVERY round."""
    rounds = 0
    while eng.scheduler.has_work() and rounds < max_rounds:
        eng.step_round()
        rounds += 1
        eng.pager.check_invariants(
            eng.prefix_cache.block_refs() if eng.prefix_cache else None)
    return eng.run()  # drains stragglers + final invariant check


def test_engine_partial_prefix_tight_pool_survives(tiny):
    """The crash end-to-end: request A caches a page and finishes; request
    B's prompt matches it mid-block in a 2-block pool. On main,
    PoolExhausted escaped `run()` on B's first prefill write. Now B
    completes — via admission's CoW reserve, with no stall fallback."""
    rng = np.random.default_rng(42)
    eng = _eng(tiny, num_blocks=2)
    a_prompt = rng.integers(0, eng.cfg.vocab, 5)
    rid_a = eng.submit(a_prompt, max_new_tokens=1)
    eng.run()
    assert eng.request(rid_a).state is RequestState.FINISHED
    assert eng.pager.free_blocks == 1 and len(eng.prefix_cache) == 1

    b_prompt = list(a_prompt[:2]) + [int(t) for t in
                                     rng.integers(0, eng.cfg.vocab, 5)]
    rid_b = eng.submit(b_prompt, max_new_tokens=1)
    stats = eng.run()  # on main: raise PoolExhausted
    req = eng.request(rid_b)
    assert req.state is RequestState.FINISHED
    assert len(req.generated) == 1
    assert stats["stalls"] == 0  # admission solved it, not stall-retry


def test_engine_pool_pressure_fuzz_tiny_pools(tiny):
    """Satellite 4: randomized workloads on 1-4 block pools; invariants
    hold after every round, nothing escapes, everything goes terminal."""
    for num_blocks in (1, 2, 3, 4):
        rng = np.random.default_rng(100 + num_blocks)
        eng = _eng(tiny, num_blocks=num_blocks)
        cap = num_blocks * eng.pager.block_size
        rids = []
        for _ in range(5):
            total = int(rng.integers(2, cap + 1))
            gen = int(rng.integers(1, min(total, 3)))
            prompt = rng.integers(0, eng.cfg.vocab, total - gen)
            rids.append(eng.submit(prompt, max_new_tokens=gen))
        stats = _drive_checked(eng)
        assert all(eng.request(r).terminal for r in rids)
        assert stats["completed"] == len(rids)  # no faults: all complete
        assert stats["failed"] == 0 and stats["live"] == 0


@pytest.mark.slow
def test_engine_pool_pressure_fuzz_long_sweep(tiny):
    """The long arm of the fuzz: more seeds, staggered arrivals, chaos on."""
    for seed in range(4):
        rng = np.random.default_rng(1000 + seed)
        num_blocks = int(rng.integers(2, 7))
        inj = FaultInjector(seed, rates={"pool_exhausted": 0.05,
                                         "reclaim_refuse": 0.1,
                                         "preempt_refuse": 0.05,
                                         "decode": 0.03, "prefill": 0.03})
        eng = _eng(tiny, num_blocks=num_blocks, faults=inj)
        cap = num_blocks * eng.pager.block_size
        rids = []
        for burst in range(3):
            for _ in range(4):
                total = int(rng.integers(2, cap + 1))
                gen = int(rng.integers(1, min(total, 4)))
                prompt = rng.integers(0, eng.cfg.vocab, total - gen)
                rids.append(eng.submit(prompt, max_new_tokens=gen))
            for _ in range(int(rng.integers(1, 5))):
                eng.step_round()
                eng.pager.check_invariants(eng.prefix_cache.block_refs())
        stats = _drive_checked(eng)
        assert all(eng.request(r).terminal for r in rids)
        assert (stats["completed"] + stats["cancelled"]
                + stats["failed"]) == len(rids)


# ----------------------------------------------------------------- chaos


def test_engine_chaos_every_request_terminal_and_replayable(tiny):
    """A seeded fault schedule (decode/prefill exceptions, pool exhaustion,
    refusals) degrades gracefully — every request terminal, invariants hold
    — and replays bit-for-bit: same outcomes, same tokens, same injector
    counts across two identical runs."""
    rates = {"pool_exhausted": 0.1, "reclaim_refuse": 0.2,
             "preempt_refuse": 0.1, "decode": 0.1, "prefill": 0.1}

    def run():
        rng = np.random.default_rng(17)
        inj = FaultInjector(5, rates=rates)
        eng = _eng(tiny, num_blocks=6, faults=inj, max_in_flight=3)
        shared = rng.integers(0, eng.cfg.vocab, 6)
        rids = []
        for i in range(6):
            prompt = rng.integers(0, eng.cfg.vocab, int(rng.integers(3, 9)))
            if i % 2 == 0:
                n = min(len(shared), len(prompt) - 1)
                prompt[:n] = shared[:n]
            rids.append(eng.submit(prompt, max_new_tokens=2))
        stats = _drive_checked(eng)
        outcomes = [(eng.request(r).state, eng.request(r).finish_reason,
                     tuple(eng.request(r).generated)) for r in rids]
        return outcomes, inj.stats(), stats

    outcomes1, inj1, stats1 = run()
    outcomes2, inj2, _ = run()
    assert all(state in TERMINAL_STATES for state, _, _ in outcomes1)
    assert outcomes1 == outcomes2
    assert inj1 == inj2
    assert inj1["injected"] == stats1["faults_injected"] > 0


def test_engine_decode_poison_quarantines_only_the_requests(tiny):
    """A decode round that always raises must not crash the engine: the
    members share the blame and are quarantined after max_request_faults,
    with their pages freed and the error recorded."""
    inj = FaultInjector(0, rates={"decode": 1.0})
    eng = _eng(tiny, num_blocks=8, faults=inj, max_request_faults=2)
    rng = np.random.default_rng(3)
    rid = eng.submit(rng.integers(0, eng.cfg.vocab, 5), max_new_tokens=3)
    stats = eng.run()
    req = eng.request(rid)
    assert req.state is RequestState.FAILED
    assert req.finish_reason == "fault"
    assert "InjectedFault" in req.error
    assert stats["failed"] == 1 and stats["step_faults"] == 3
    assert stats["completed"] == 0
    # quarantine freed the request's pages; only cached pages remain
    assert eng.pager.free_blocks + len(eng.prefix_cache) == 8


def test_engine_prefill_poison_quarantines(tiny):
    inj = FaultInjector(0, rates={"prefill": 1.0})
    eng = _eng(tiny, faults=inj, max_request_faults=2)
    rid = eng.submit([5, 6, 7, 8, 9], max_new_tokens=2)
    stats = eng.run()
    req = eng.request(rid)
    assert req.state is RequestState.FAILED and req.finish_reason == "fault"
    assert stats["failed"] == 1


def test_engine_recovers_from_transient_fault(tiny):
    """One injected decode failure, then clear air: the request retries the
    round and completes — transient faults cost a round, not the request."""
    inj = FaultInjector(0, rates={"decode": 1.0}, max_faults=1)
    eng = _eng(tiny, faults=inj)
    rid = eng.submit([3, 1, 4, 1, 5], max_new_tokens=4)
    stats = eng.run()
    req = eng.request(rid)
    assert req.state is RequestState.FINISHED
    assert len(req.generated) == 4
    assert stats["step_faults"] == 1 and stats["failed"] == 0
    assert req.fault_count == 0  # success cleared the shared blame


# ----------------------------------------- deadlines / cancel / shed / run


def test_engine_deadline_expires_and_overrides(tiny):
    """Engine-default deadline 0 cancels at the first round boundary; a
    generous per-request override completes normally."""
    done = []
    eng = _eng(tiny, deadline_s=0.0,
               on_finish=lambda r: done.append(r.rid))
    doomed = [eng.submit([1, 2, 3], max_new_tokens=2) for _ in range(2)]
    saved = eng.submit([4, 5, 6], max_new_tokens=2, deadline_s=60.0)
    stats = eng.run()
    for rid in doomed:
        req = eng.request(rid)
        assert req.state is RequestState.CANCELLED
        assert req.finish_reason == "deadline"
    assert eng.request(saved).state is RequestState.FINISHED
    assert stats["deadline_expired"] == 2 and stats["cancelled"] == 2
    assert stats["completed"] == 1
    assert sorted(done) == sorted(doomed + [saved])  # on_finish fired for all


def test_engine_cancel_mid_flight(tiny):
    done = []
    eng = _eng(tiny, on_finish=lambda r: done.append(r.rid))
    rng = np.random.default_rng(4)
    r0 = eng.submit(rng.integers(0, eng.cfg.vocab, 6), max_new_tokens=6)
    r1 = eng.submit(rng.integers(0, eng.cfg.vocab, 6), max_new_tokens=6)
    eng.step_round()
    eng.step_round()  # both in flight now
    assert eng.cancel(r0) is True
    assert eng.cancel(r0) is False  # already terminal: idempotent
    assert eng.cancel(999) is False  # unknown rid
    eng.pager.check_invariants(eng.prefix_cache.block_refs())  # pages freed
    stats = eng.run()
    assert eng.request(r0).state is RequestState.CANCELLED
    assert eng.request(r0).finish_reason == "cancelled"
    assert eng.request(r1).state is RequestState.FINISHED
    assert len(eng.request(r1).generated) == 6
    assert stats["cancelled"] == 1 and r0 in done and r1 in done


def test_engine_sheds_on_admission_overflow(tiny):
    done = []
    eng = _eng(tiny, max_queue=2, on_finish=lambda r: done.append(r.rid))
    rng = np.random.default_rng(6)
    rids = [eng.submit(rng.integers(0, eng.cfg.vocab, 4), max_new_tokens=1)
            for _ in range(5)]
    shed = [r for r in rids if eng.request(r).state is RequestState.FAILED]
    assert len(shed) == 3  # queue held 2; the rest were shed at submit
    for rid in shed:
        assert eng.request(rid).finish_reason == "shed"
        assert rid in done  # the callback contract holds for shed too
    stats = eng.run()
    assert stats["completed"] == 2 and stats["shed"] == 3
    assert stats["failed"] == 3


def test_engine_run_returns_partial_stats_when_wedged(tiny):
    """A workload that can never be admitted must not spin `run()` forever
    or raise: past the idle bound the remainder is CANCELLED ("stalled")
    and the stats come back with the accounting."""
    eng = _eng(tiny, num_blocks=4)
    eng.pager.alloc(999, eng.pager.pool_tokens)  # squatter pins every block
    rid = eng.submit([1, 2, 3, 4], max_new_tokens=2)
    stats = eng.run(max_idle_rounds=3)
    req = eng.request(rid)
    assert req.state is RequestState.CANCELLED
    assert req.finish_reason == "stalled"
    assert stats["stalled"] == 1 and stats["live"] == 0


def test_engine_run_respects_max_rounds(tiny):
    eng = _eng(tiny)
    rid = eng.submit([9, 8, 7], max_new_tokens=4)
    stats = eng.run(max_rounds=0)
    assert eng.request(rid).finish_reason == "stalled"
    assert stats["stalled"] == 1


# ------------------------------------------------- table width (satellite 2)


def test_engine_table_width_tracks_live_requests_only(tiny):
    """The decode-table width follows the LIVE worst case with a high-water
    guard: one long retired request no longer pins the width forever, and
    lookups still resolve through the retired map."""
    rng = np.random.default_rng(8)
    eng = _eng(tiny, num_blocks=16)
    assert eng._table_width() == 1
    short = eng.submit(rng.integers(0, eng.cfg.vocab, 3), max_new_tokens=2)
    long = eng.submit(rng.integers(0, eng.cfg.vocab, 30), max_new_tokens=2)
    assert eng._table_width() == 8  # blocks_for(32): the long request
    stats = eng.run()
    assert stats["completed"] == 2
    assert eng._requests == {}  # terminal requests leave the live map
    assert eng.request(long).state is RequestState.FINISHED  # still findable
    assert eng.request(short).state is RequestState.FINISHED
    assert eng._table_width() == 1  # the mark fell with the live need
    # the shrink is hysteretic: a mid-size live request re-grows cleanly
    mid = eng.submit(rng.integers(0, eng.cfg.vocab, 14), max_new_tokens=2)
    assert eng._table_width() == 4
    eng.run()
    assert eng.request(mid).state is RequestState.FINISHED


# ----------------------------------------------------------- chaos harness


@pytest.mark.slow
def test_chaos_serve_script_smoke():
    """scripts/chaos_serve.py (the CI chaos-smoke lane) runs green and its
    summary accounts for every request."""
    root = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "scripts/chaos_serve.py", "--seed", "1",
         "--rounds", "30", "--requests", "4"],
        cwd=root, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    summary = json.loads(proc.stdout.strip().splitlines()[-1])
    assert summary["requests"] == 4
    assert (summary["completed"] + summary["cancelled"]
            + summary["failed"]) == 4

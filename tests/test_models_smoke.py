"""Per-arch smoke tests: reduced config, one train step + prefill/decode on CPU.

Full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and tests/test_dryrun_mini.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCH_NAMES, REGISTRY, token_split
from repro.models import build_model
from repro.optim import AdamWConfig, init_state
from repro.runtime.steps import make_train_step


def _batch(cfg, b, s, rng):
    front, text = token_split(cfg, s)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (b, text)), jnp.int32),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab, (b, text)), jnp.int32),
        "positions": jnp.tile(jnp.arange(text, dtype=jnp.int32), (b, 1)),
    }
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.randn(b, front, cfg.d_model) * 0.02, jnp.float32)
    if cfg.vlm:
        batch["patches"] = jnp.asarray(rng.randn(b, front, cfg.d_model) * 0.02, jnp.float32)
    return batch, text


@pytest.mark.parametrize("arch", ALL_ARCH_NAMES)
def test_arch_train_step(arch, rng):
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    batch, _ = _batch(cfg, 2, 32, rng)
    step = jax.jit(make_train_step(model, AdamWConfig(total_steps=10)))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(state["params"]), jax.tree.leaves(new_state["params"])))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ALL_ARCH_NAMES)
def test_arch_prefill_decode_shapes(arch, rng):
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch, text = _batch(cfg, 2, 32, rng)
    cache, logits = model.prefill(params, batch, pad_to=text + 4)
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    dbatch = {"tokens": jnp.ones((2, 1), jnp.int32),
              "positions": jnp.full((2, 1), text, jnp.int32)}
    logits2, cache2 = model.decode_step(params, cache, dbatch)
    assert logits2.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-moe-30b-a3b", "mamba2-130m",
                                  "hymba-1.5b", "whisper-medium", "paligemma-3b"])
def test_decode_matches_full_forward(arch, rng):
    """prefill(prompt[:-1]) + decode(last) == prefill(prompt) last logits."""
    cfg = REGISTRY[arch].reduced().replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch, text = _batch(cfg, 2, 33, rng)
    _, logits_full = model.prefill(params, batch)
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :-1]
    short["targets"] = batch["targets"][:, :-1]
    short["positions"] = batch["positions"][:, :-1]
    cache, _ = model.prefill(params, short, pad_to=text + 4)
    dbatch = {"tokens": batch["tokens"][:, -1:],
              "positions": jnp.full((2, 1), text - 1, jnp.int32)}
    logits_dec, _ = model.decode_step(params, cache, dbatch)
    rel = float(jnp.abs(logits_full - logits_dec).max()) / float(jnp.abs(logits_full).max())
    assert rel < 2e-4, f"{arch}: decode/full mismatch rel={rel}"


def test_mamba2_split_proj_trains(rng):
    """§Perf shard-aligned SSD layout: same family, different param layout."""
    import jax
    import jax.numpy as jnp
    from repro.optim import AdamWConfig, init_state
    from repro.runtime.steps import make_train_step
    cfg = REGISTRY["mamba2-130m"].reduced().replace(ssm_split_proj=True)
    model = build_model(cfg)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    batch, _ = _batch(cfg, 2, 32, rng)
    st, metrics = jax.jit(make_train_step(model, AdamWConfig(total_steps=5)))(state, batch)
    assert np.isfinite(float(metrics["loss"]))

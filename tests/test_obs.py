"""Observability layer (ISSUE-8): Chrome-trace schema validation over an
end-to-end paged-serve run, the REPRO_TELEMETRY=0 null path, the shared
percentile/histogram/registry machinery, and the Fig. 14-style stall
breakdown's sum-to-wall-time invariant."""
import json

import numpy as np
import pytest

import repro.obs as obs
from repro.configs import get_config
from repro.core import autotune
from repro.core.schedule import TileProfile
from repro.obs import breakdown, metrics, trace
from repro.serve import PagedServingEngine


def _f32_cfg():
    return get_config("yi-6b").reduced().replace(dtype="float32",
                                                 param_dtype="float32")


def _pressured_prefix_run():
    """A paged run that exercises every instant event: a shared system
    prefix diverging mid-block (COW fork), a pool tight enough to reclaim
    cache-only pages (evict) and preempt an in-flight request."""
    cfg = _f32_cfg()
    rng = np.random.default_rng(3)
    shared = list(rng.integers(0, cfg.vocab, 6))  # 1.5 blocks at blk=4
    prompts = [shared + list(rng.integers(0, cfg.vocab, 18 + 3 * i))
               for i in range(3)]
    eng = PagedServingEngine(cfg, block_size=4, num_blocks=14,
                             prefix_cache=True)
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    stats = eng.run()
    return eng, stats


# ----------------------------------------------------------- trace schema


def _validate_chrome_trace(doc):
    """Schema-check a Chrome trace-event container: required keys per
    phase, and complete spans properly nested per track (each pair of "X"
    spans on one tid either disjoint or contained)."""
    assert isinstance(doc, dict) and isinstance(doc["traceEvents"], list)
    events = doc["traceEvents"]
    assert events, "trace must not be empty"
    for ev in events:
        assert ev["ph"] in ("X", "i", "b", "e"), ev
        for key in ("name", "ts", "pid", "tid"):
            assert key in ev, (key, ev)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
        if ev["ph"] == "i":
            assert ev["s"] == "t"
        if ev["ph"] in ("b", "e"):
            assert "id" in ev and "cat" in ev
    # nesting: on each tid, sort spans by (start, -dur); a running stack of
    # enclosing spans must always contain the next span or be disjoint
    by_tid = {}
    for ev in events:
        if ev["ph"] == "X":
            by_tid.setdefault(ev["tid"], []).append(ev)
    eps = 1e-3  # us slack: enter/exit clock reads are not atomic
    for tid, spans in by_tid.items():
        spans.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for ev in spans:
            while stack and ev["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - eps:
                stack.pop()
            if stack:  # must be fully inside the enclosing span
                outer = stack[-1]
                assert ev["ts"] + ev["dur"] <= \
                    outer["ts"] + outer["dur"] + eps, (tid, outer, ev)
            stack.append(ev)
    return events


def test_trace_schema_and_lifecycle_events(tmp_path):
    """Acceptance: a prefix-cache paged run emits valid Chrome trace JSON
    with request-lifecycle spans, pipeline spans carrying depth/n_tiles
    attributes, and COW/evict/preempt instant events."""
    eng, stats = _pressured_prefix_run()
    path = tmp_path / "trace.json"
    trace.get_tracer().export(str(path))
    events = _validate_chrome_trace(json.loads(path.read_text()))

    names = {ev["name"] for ev in events}
    assert {"round", "decode_round", "prefill_chunk", "prefix_lookup",
            "admit"} <= names

    # the workload really did fork/evict/preempt (else the instants can't
    # be there) — and the instants are there
    assert stats["cow_forks"] >= 1 and stats["preemptions"] >= 1
    assert stats["cache_evictions"] >= 1
    instants = {ev["name"] for ev in events if ev["ph"] == "i"}
    assert {"cow_fork", "cache_evict", "preempt"} <= instants

    # request lifecycle: every submitted rid opens and closes an async span
    begins = {ev["id"] for ev in events
              if ev["ph"] == "b" and ev["name"] == "request"}
    ends = {ev["id"] for ev in events
            if ev["ph"] == "e" and ev["name"] == "request"}
    assert begins == ends == {0, 1, 2}

    # pipeline spans carry the §2.5 attributes
    pipes = [ev for ev in events if ev["name"] == "pipeline:paged_decode"]
    assert pipes
    for ev in pipes:
        assert ev["args"]["depth"] == stats["solved_depth"]
        assert ev["args"]["n_tiles"] >= 0
        assert ev["args"]["context_bytes"] > 0


def test_coro_call_pipeline_span_attributes():
    """A real kernel launch through coro_call lands one pipeline span with
    depth / n_tiles / context-bytes attributes on the kernel track."""
    import jax.numpy as jnp

    from repro.kernels.coro_gather.ops import coro_gather

    rng = np.random.RandomState(0)
    table = jnp.asarray(rng.randn(64, 128), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, 32), jnp.int32)
    coro_gather(table, idx)
    evs = [ev for ev in trace.get_tracer().events
           if ev["name"] == "pipeline:row_gather"]
    assert evs, "coro_call must emit a pipeline span"
    ev = evs[-1]
    assert ev["tid"] == trace.TID_KERNEL
    assert ev["args"]["depth"] >= 1
    assert ev["args"]["n_tiles"] == 4  # 32 idx / 8 rows per tile
    assert ev["args"]["context_bytes"] > 0


def test_trace_export_via_launch_serve_flag(tmp_path):
    """`launch/serve.py --engine paged --trace out.json` writes a valid,
    non-empty Chrome trace (the ci.sh lane's contract)."""
    from repro.launch import serve as launch_serve

    path = tmp_path / "out.json"
    stats = launch_serve.main([
        "--arch", "yi-6b", "--reduced", "--batch", "2",
        "--prompt-len", "16", "--gen", "3", "--engine", "paged",
        "--block-size", "4", "--trace", str(path)])
    assert stats["trace"] == str(path)
    assert path.stat().st_size > 0
    events = _validate_chrome_trace(json.loads(path.read_text()))
    names = {ev["name"] for ev in events}
    assert "round" in names and "pipeline:paged_decode" in names


# ------------------------------------------------------------- null path


def test_disabled_tracer_and_registry_allocate_nothing():
    """REPRO_TELEMETRY=0 path: module-level null objects, no event storage,
    no per-call allocation (span() returns one shared context manager)."""
    obs.set_enabled(False)
    tracer = trace.get_tracer()
    assert tracer is trace.NULL_TRACER
    s1 = tracer.span("a", depth=3)
    with tracer.span("b"):
        tracer.instant("cow_fork", src=1, dst=2)
        tracer.complete("pipeline:x", 0.0, 1.0, depth=2)
        tracer.begin_async("request", 0)
        tracer.end_async("request", 0)
    assert tracer.span("c") is s1  # the one shared null span: no allocation
    assert len(tracer.events) == 0 and tracer.to_dict()["traceEvents"] == []

    reg = metrics.new_registry()
    assert reg is metrics.NULL_REGISTRY
    c = reg.counter("x")
    c.inc(5)
    h = reg.histogram("y")
    h.observe(1.0)
    assert c.value == 0 and h.count == 0 and h.samples == []
    assert reg.counter("z") is c  # shared singleton metric objects
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert metrics.default_registry() is metrics.NULL_REGISTRY
    assert reg.prometheus_text() == ""

    # an engine built while disabled still serves correctly; its stats
    # degrade to registry zeros rather than erroring
    cfg = _f32_cfg()
    eng = PagedServingEngine(cfg, block_size=4, num_blocks=32,
                             prefix_cache=True)
    eng.submit(list(range(1, 9)), max_new_tokens=2)
    stats = eng.run()
    assert stats["completed"] == 1
    assert stats["p50_ms"] == 0.0 and stats["prefix_hits"] == 0
    assert len(trace.get_tracer().events) == 0

    obs.set_enabled(True)
    assert trace.get_tracer() is not trace.NULL_TRACER


def test_env_seeds_disabled_state(monkeypatch):
    monkeypatch.setenv("REPRO_TELEMETRY", "0")
    obs.reset()
    assert trace.get_tracer() is trace.NULL_TRACER
    assert metrics.default_registry() is metrics.NULL_REGISTRY
    monkeypatch.setenv("REPRO_TELEMETRY", "1")
    obs.reset()
    assert trace.get_tracer() is not trace.NULL_TRACER


# ------------------------------------------------------ metrics registry


def test_histogram_percentiles_and_report():
    reg = metrics.MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    xs = [0.05, 0.5, 0.7, 2.0, 20.0]
    for x in xs:
        h.observe(x)
    assert h.count == 5 and h.bucket_counts == [1, 2, 1, 1]
    assert h.percentile(0.50) == metrics.percentile(xs, 0.50) == 0.7
    assert h.percentile(0.99) == 20.0
    rep = h.report()
    assert rep["count"] == 5 and rep["p50"] == 0.7

    # the sample ring is bounded like autotune's store
    h2 = metrics.Histogram("b", buckets=(1.0,), max_samples=8)
    for i in range(100):
        h2.observe(float(i))
    assert len(h2.samples) == 8 and h2.count == 100
    assert h2.samples == [float(i) for i in range(92, 100)]


def test_latency_report_is_the_one_shared_implementation():
    """The engine and launch.serve percentile copies are gone: both route
    through obs.metrics.latency_report."""
    from repro.launch import serve as launch_serve
    from repro.serve import engine as serve_engine

    assert serve_engine.latency_report is metrics.latency_report
    assert launch_serve.latency_report is metrics.latency_report
    assert not hasattr(autotune, "_percentile")
    rep = metrics.latency_report([0.001, 0.002, 0.003])
    assert rep == {"p50_ms": 2.0, "p99_ms": 3.0, "mean_ms": 2.0}
    assert metrics.latency_report([]) == {"p50_ms": 0.0, "p99_ms": 0.0,
                                          "mean_ms": 0.0}


def test_registry_snapshot_prometheus_and_views():
    reg = metrics.MetricsRegistry()
    reg.counter("serve.prefix_hits").inc(3)
    reg.gauge("pool.free_blocks").set(7)
    h = reg.histogram("serve.token_latency_s", buckets=(0.01, 0.1))
    h.observe(0.05)
    reg.view("extra", lambda: {"k": 1})
    snap = reg.snapshot()
    assert snap["counters"]["serve.prefix_hits"] == 3
    assert snap["gauges"]["pool.free_blocks"] == 7
    assert snap["histograms"]["serve.token_latency_s"]["count"] == 1
    assert snap["extra"] == {"k": 1}

    text = reg.prometheus_text()
    assert "# TYPE serve_prefix_hits counter" in text
    assert "serve_prefix_hits 3" in text
    assert '# TYPE serve_token_latency_s histogram' in text
    assert 'serve_token_latency_s_bucket{le="0.01"} 0' in text
    assert 'serve_token_latency_s_bucket{le="+Inf"} 1' in text

    with pytest.raises(TypeError):
        reg.gauge("serve.prefix_hits")  # name already a counter


def test_default_registry_serves_autotune_view():
    """telemetry_summary is a VIEW of the default registry: one snapshot
    covers the kernel feedback loop (ISSUE-8 acceptance)."""
    autotune.record_transfer("viewk", 1e-4)
    snap = metrics.default_registry().snapshot()
    assert snap["autotune"]["kernels"]["viewk"]["samples"] == 1
    assert snap["autotune"] == autotune.telemetry_summary()


def test_engine_stats_are_registry_views():
    eng, stats = _pressured_prefix_run()
    snap = eng.metrics.snapshot()
    assert snap["counters"]["serve.prefix_hits"] == stats["prefix_hits"] > 0
    assert snap["counters"]["serve.cow_forks"] == stats["cow_forks"] >= 1
    assert snap["histograms"]["serve.token_latency_s"]["count"] > 0
    assert snap["histograms"]["serve.ttft_s"]["count"] == stats["completed"]
    assert "serve_cow_forks" in eng.metrics.prometheus_text()
    # two engines never share a registry
    assert PagedServingEngine(
        _f32_cfg(), block_size=4, num_blocks=8).metrics is not eng.metrics


# ----------------------------------------------------- stall breakdown


def test_breakdown_attribution_sums_to_observed():
    """Acceptance: compute + transfer + gap == observed wall time (within
    10%; exact by construction, modulo rounding) across regimes."""
    p = TileProfile(tile_bytes=1 << 20, flops_per_tile=1e6)
    for depth in (1, 2, 8, 64):
        for w in (1e-6, 5e-5, 3e-3):
            bd = breakdown.attribute(p, depth, w)
            total = bd["compute_us"] + bd["transfer_us"] + bd["gap_us"]
            assert total == pytest.approx(bd["observed_us"], rel=0.1)
            assert bd["compute_frac"] + bd["transfer_frac"] + \
                bd["gap_frac"] == pytest.approx(1.0, abs=0.01)
    # a compute-bound tile at generous depth attributes mostly to compute
    heavy = TileProfile(tile_bytes=1024, flops_per_tile=1e9)
    from repro.core.schedule import tile_compute_s
    tc = tile_compute_s(heavy)
    bd = breakdown.attribute(heavy, 64, tc * 1.01)
    assert bd["compute_frac"] > 0.9


def test_breakdown_in_telemetry_summary_and_report():
    """choose_depth records the tile profile; once samples land, the
    summary (and stall_breakdown over it) carries the attribution."""
    p = TileProfile(tile_bytes=1 << 16, flops_per_tile=1e5)
    depth = autotune.choose_depth(p, kernel="bdk")
    assert autotune.last_profile("bdk") == p
    for _ in range(4):
        autotune.record_transfer("bdk", 2e-4)
    entry = autotune.telemetry_summary()["kernels"]["bdk"]
    bd = entry["breakdown"]
    assert bd["depth"] == depth
    assert bd["observed_us"] == pytest.approx(entry["p50_us"], rel=1e-6)
    total = bd["compute_us"] + bd["transfer_us"] + bd["gap_us"]
    assert total == pytest.approx(bd["observed_us"], rel=0.1)

    rep = breakdown.stall_breakdown()
    assert rep["kernels"]["bdk"] == bd

    # kernels observed without a profile report unattributed time
    autotune.record_transfer("no_profile_kernel", 1e-4)
    rep = breakdown.stall_breakdown()
    assert rep["kernels"]["no_profile_kernel"]["unattributed"] is True


def test_breakdown_sums_for_live_paged_decode():
    """End-to-end half of the acceptance criterion: the breakdown the
    serving engine's decode rounds produce sums to their observed per-tile
    wall time."""
    _eng, _stats = _pressured_prefix_run()
    entry = autotune.telemetry_summary()["kernels"]["paged_decode"]
    assert entry["samples"] > 0
    bd = entry["breakdown"]
    total = bd["compute_us"] + bd["transfer_us"] + bd["gap_us"]
    assert total == pytest.approx(bd["observed_us"], rel=0.1)


def test_kernel_bench_json_carries_breakdown_and_metrics(tmp_path):
    """`kernel_bench --json` embeds the registry snapshot and per-kernel
    breakdowns; `--trace` writes a valid trace of the bench run."""
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.kernel_bench import json_report

    rep = json_report()
    assert "metrics" in rep and "autotune" in rep["metrics"]
    entry = rep["kernels"]["row_gather"]
    assert entry["samples"] > 0 and entry["breakdown"] is not None
    bd = entry["breakdown"]
    total = bd["compute_us"] + bd["transfer_us"] + bd["gap_us"]
    assert total == pytest.approx(bd["observed_us"], rel=0.1)


def test_tracer_ring_bounds_memory():
    tr = trace.Tracer(capacity=16)
    for i in range(100):
        tr.instant(f"e{i}")
    assert len(tr.events) == 16 and tr.dropped == 84
    assert [ev["name"] for ev in tr.events][0] == "e84"

"""Declarative `CoroSpec` substrate: derivation rules, edge cases, parity.

Covers the ISSUE-2 acceptance criteria:
  * scratch derivation — per-slot (depth, *tile) buffers for streams,
    classified shapes for context vars (private x depth, shared x 1);
  * `choose_depth` consuming the classified context bytes: a shared
    accumulator permits a strictly deeper pipeline than the all-private
    baseline;
  * `context.max_depth` never returns the old unbounded sentinel;
  * pipeline edge cases — depth > n_tiles clamping, depth <= 0 rejection,
    grid mode with n_tiles == 1 (warmup + epilogue drain on one step);
  * old-vs-new API numerical parity on every kernel family (seeded
    sweeps, no hypothesis): the declarative entry points match the same
    oracles the hand-rolled kernels matched, at explicit depths and at
    ``depth=None``.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.context import MAX_DEPTH, VarClass, VarSpec, max_depth, var
from repro.core.coro import CoroSpec, LoadStream, coro_loop
from repro.core.schedule import TileProfile
from repro.kernels.coro_gather.coro_gather import row_gather_spec
from repro.kernels.coro_gather.ops import coro_gather
from repro.kernels.coro_gather.ref import gather_ref
from repro.kernels.coro_scatter_add.ops import coro_scatter_add
from repro.kernels.coro_scatter_add.ref import scatter_add_ref
from repro.kernels.decode_attention.decode_attention import decode_spec
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_spec
from repro.kernels.stream_copy.ops import stream_triad
from repro.kernels.stream_copy.ref import triad_ref


# ------------------------------------------------------- spec derivation


def test_stream_slots_are_private_context():
    spec = row_gather_spec(8, 64, jnp.float32)
    sv = spec.stream_vars()
    assert [v.name for v in sv] == ["rows"]
    assert sv[0].nbytes == 8 * 64 * 4
    # a slot is rewritten every rotation from its own tile only -> private,
    # so context_bytes scales with depth
    assert spec.context_bytes(8) == 8 * spec.context_bytes(1)


def test_decode_spec_context_is_depth_independent_for_accumulators():
    spec = decode_spec(32, 2, 2, 16, jnp.float32)
    d1, d8 = spec.context_bytes(1), spec.context_bytes(8)
    slot_bytes = sum(s.nbytes for s in spec.loads)
    # only the k/v slots multiply by depth; m/l/acc/q stay x1
    assert d8 - d1 == 7 * slot_bytes
    # the all-private baseline (conventional coroutine frames) is strictly
    # larger at any depth > 1 — Fig. 15's context-minimization gain
    assert spec.context_bytes(8, baseline=True) > d8


def test_scratch_shapes_follow_classification():
    spec = ssd_spec(16, 2, 8, 16, jnp.float32, seq_len=64)
    shapes = spec.scratch_shapes(depth=5)
    # 4 load slots + 1 load semaphore + 1 materialized var (the h state)
    assert len(shapes) == 6
    assert shapes[0].shape == (5, 16, 2, 8)       # x slots: private x depth
    assert shapes[-1].shape == (2, 8, 16)         # h state: sequential x 1


def test_spec_rejects_duplicate_names():
    with pytest.raises(ValueError, match="duplicate"):
        CoroSpec(
            name="dup",
            loads=(LoadStream("a", (1, 1), jnp.float32, src=lambda c, t: None),),
            vars=(VarSpec("a", 4),),
        )


def test_stream_rejects_indivisible_group():
    # tile[0]=10 over group=4 would silently truncate to 8 rows per slot
    with pytest.raises(ValueError, match="group"):
        LoadStream("rows", (10, 4), jnp.float32, src=lambda c, t: [], group=4)


def test_last_choice_reports_clamped_depth(rng):
    """The recorded auto-depth is the one the kernel ran with, never the
    solver's raw (possibly > n_tiles, unallocatable) answer."""
    table = jnp.asarray(rng.randn(64, 16), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, 16), jnp.int32)  # 2 tiles
    coro_gather(table, idx)  # solver wants far more than 2 slots
    assert autotune.last_choice("row_gather") == 2


def test_var_helper_derives_nbytes():
    v = var("h", (2, 8, 16), jnp.float32, carries_dependence=True)
    assert v.nbytes == 2 * 8 * 16 * 4
    assert v.shape == (2, 8, 16)


# ------------------------------------------- classified VMEM cap in autotune


def test_shared_accumulator_permits_deeper_pipeline():
    """The ISSUE-2 criterion: choose_depth(vars=...) caps from classified
    context bytes, so a commutative (shared) accumulator reaches a strictly
    deeper pipeline than the same bytes classified private."""
    slot = VarSpec("slot", 1 << 20)  # the stream slot: private
    acc = VarSpec("acc", 1 << 20, carries_dependence=True, commutative=True)
    acc_private = dataclasses.replace(acc, hint=VarClass.PRIVATE)
    profile = TileProfile(tile_bytes=1 << 20, flops_per_tile=1.0)
    budget = 8 << 20
    kw = dict(latency_s=20e-6, vmem_budget=budget)
    d_shared = autotune.choose_depth(profile, vars=[slot, acc], **kw)
    d_private = autotune.choose_depth(profile, vars=[slot, acc_private], **kw)
    assert d_shared > d_private
    assert d_shared == 7   # (8MB - 1MB shared) // 1MB per slot
    assert d_private == 4  # 8MB // 2MB per slot


def test_max_depth_sentinel_is_clamped():
    # all-shared context: no per-slot bytes — the old code returned 2**30
    vs = [VarSpec("ro", 64, read_only=True)]
    assert max_depth(vs, 1 << 20) == MAX_DEPTH
    assert max_depth(vs, 1) == 0  # shared alone overflows the budget
    # and the general case is request-slot capped too
    vs = [VarSpec("tiny", 1)]
    assert max_depth(vs, 1 << 30) == MAX_DEPTH


# ----------------------------------------------------- pipeline edge cases


def test_coro_loop_nonpositive_depth_is_noop():
    called = []
    out = coro_loop(4, 0, called.append, lambda t, s, c: c, called.append,
                    carry_init=7)
    assert out == 7 and not called


@pytest.mark.parametrize("bad", [0, -3])
def test_entry_points_reject_nonpositive_depth(rng, bad):
    table = jnp.asarray(rng.randn(32, 16), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 32, 8), jnp.int32)
    with pytest.raises(ValueError, match="depth"):
        coro_gather(table, idx, depth=bad)
    b = jnp.asarray(rng.randn(64, 8), jnp.float32)
    with pytest.raises(ValueError, match="depth"):
        stream_triad(b, b, 1.0, rows=32, depth=bad)


def test_depth_exceeding_n_tiles_is_clamped(rng):
    table = jnp.asarray(rng.randn(64, 16), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, 16), jnp.int32)  # 2 tiles
    out = coro_gather(table, idx, depth=64)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(gather_ref(table, idx)))
    b = jnp.asarray(rng.randn(64, 8), jnp.float32)
    c = jnp.asarray(rng.randn(64, 8), jnp.float32)
    out = stream_triad(b, c, 2.0, rows=32, depth=50)  # 2 tiles
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(triad_ref(b, c, 2.0)),
                               rtol=1e-5, atol=1e-5)


def test_grid_mode_single_tile(rng):
    """n_tiles == 1: warmup, consume, store issue and epilogue drain all on
    the one grid step."""
    table = jnp.asarray(rng.randn(32, 16), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 32, 8), jnp.int32)
    out = coro_gather(table, idx)  # 8 idx / rows_per_tile 8 -> 1 tile
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(gather_ref(table, idx)))

    b = jnp.asarray(rng.randn(32, 8), jnp.float32)
    c = jnp.asarray(rng.randn(32, 8), jnp.float32)
    out = stream_triad(b, c, 1.5, rows=32)  # n == rows -> 1 tile
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(triad_ref(b, c, 1.5)),
                               rtol=1e-5, atol=1e-5)

    uniq = np.asarray(rng.permutation(32)[:8], np.int32)  # 1 RMW tile
    upd = jnp.asarray(rng.randn(8, 16), jnp.float32)
    out = coro_scatter_add(table, uniq, upd)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(scatter_add_ref(table, uniq, upd)),
        rtol=1e-6, atol=1e-6)


def test_fori_mode_single_tile(rng):
    q = jnp.asarray(rng.randn(1, 4, 16), jnp.float32)
    kv = jnp.asarray(rng.randn(1, 32, 2, 16), jnp.float32)
    out = decode_attention(q, kv, kv, 20, blk=32)  # s == blk -> 1 block
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(decode_attention_ref(q, kv, kv, 20)),
        rtol=2e-5, atol=2e-5)


# ------------------------------------------------ parity: all six families
#
# The hand-rolled kernels matched these oracles before the CoroSpec port;
# the declarative entry points must match them identically, both at swept
# explicit depths and with the autotuned depth=None.


@pytest.mark.parametrize("depth", [1, 2, 5, None])
def test_parity_row_gather(rng, depth):
    table = jnp.asarray(rng.randn(96, 32) * 5, jnp.float32)
    idx = jnp.asarray(rng.randint(0, 96, 40), jnp.int32)
    out = coro_gather(table, idx, depth=depth)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(gather_ref(table, idx)))


@pytest.mark.parametrize("depth", [1, 3, None])
def test_parity_scatter_add(rng, depth):
    table = jnp.asarray(rng.randn(48, 16), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 48, 30), jnp.int32)
    upd = jnp.asarray(rng.randn(30, 16), jnp.float32)
    out = coro_scatter_add(table, idx, upd, depth=depth)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(scatter_add_ref(table, idx, upd)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("depth", [1, 2, None])
def test_parity_decode_attention(rng, depth):
    q = jnp.asarray(rng.randn(2, 4, 16), jnp.float32)
    k = jnp.asarray(rng.randn(2, 128, 2, 16), jnp.float32)
    v = jnp.asarray(rng.randn(2, 128, 2, 16), jnp.float32)
    out = decode_attention(q, k, v, 97, blk=32, depth=depth)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(decode_attention_ref(q, k, v, 97)),
        rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("depth", [1, 2, None])
def test_parity_moe_gmm(rng, depth):
    t = jnp.asarray(rng.randn(2, 8, 16), jnp.float32)
    w = jnp.asarray(rng.randn(2, 16, 256), jnp.float32)
    out = moe_gmm(t, w, f_tile=64, depth=depth)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gmm_ref(t, w)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("depth", [1, 2, None])
def test_parity_ssd(rng, depth):
    x = jnp.asarray(rng.randn(1, 64, 2, 8), jnp.float32)
    dt = jnp.asarray(rng.rand(1, 64, 2) * 0.5 + 0.1, jnp.float32)
    A = jnp.asarray(-np.exp(rng.randn(2) * 0.3), jnp.float32)
    B = jnp.asarray(rng.randn(1, 64, 16), jnp.float32)
    C = jnp.asarray(rng.randn(1, 64, 16), jnp.float32)
    y, hf = ssd(x, dt, A, B, C, chunk=16, depth=depth)
    yr, hr = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("depth", [1, 4, None])
def test_parity_triad(rng, depth):
    b = jnp.asarray(rng.randn(256, 16), jnp.float32)
    c = jnp.asarray(rng.randn(256, 16), jnp.float32)
    out = stream_triad(b, c, 3.0, rows=64, depth=depth)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(triad_ref(b, c, 3.0)),
                               rtol=1e-5, atol=1e-5)

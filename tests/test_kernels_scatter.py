"""coro_scatter_add: pipelined RMW with dedup vs oracle.

Property tests run as seeded `parametrize` sweeps (no hard hypothesis dep).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.coro_scatter_add.ops import coro_scatter_add
from repro.kernels.coro_scatter_add.ref import scatter_add_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,k", [(64, 32, 40), (128, 64, 50)])
def test_scatter_add_matches_ref(rng, dtype, n, d, k):
    table = jnp.asarray(rng.randn(n, d), dtype)
    idx = jnp.asarray(rng.randint(0, n, k), jnp.int32)
    upd = jnp.asarray(rng.randn(k, d), dtype)
    out = coro_scatter_add(table, idx, upd)
    ref = scatter_add_ref(table, idx, upd)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("seed,k", [(0, 1), (1, 5), (2, 17), (3, 40), (4, 27),
                                    (5, 33)])
def test_scatter_add_duplicates_accumulate(seed, k):
    idx = np.asarray(np.random.RandomState(seed).randint(0, 32, k), np.int32)
    table = jnp.zeros((32, 8), jnp.float32)
    upd = jnp.ones((idx.shape[0], 8), jnp.float32)
    out = coro_scatter_add(table, idx, upd)
    counts = np.zeros(32)
    np.add.at(counts, idx, 1.0)
    np.testing.assert_allclose(np.asarray(out)[:, 0], counts, atol=1e-6)

"""Attention implementations agree: naive / chunked / swa_block / ring decode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import (
    attention_chunked,
    attention_naive,
    attention_swa_block,
    decode_attention,
)
from repro.models.lm import ring_decode_attention
from repro.sharding import NULL_CTX


def _qkv(rng, b, s, h, kh, d):
    return (jnp.asarray(rng.randn(b, s, h, d), jnp.float32),
            jnp.asarray(rng.randn(b, s, kh, d), jnp.float32),
            jnp.asarray(rng.randn(b, s, kh, d), jnp.float32))


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("chunk", [16, 32])
def test_chunked_matches_naive(rng, window, chunk):
    q, k, v = _qkv(rng, 2, 64, 4, 2, 16)
    pos = jnp.arange(64)
    ref = attention_naive(q, k, v, q_pos=pos, k_pos=pos, causal=True, window=window)
    out = attention_chunked(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                            window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_chunked_unrolled_matches_scan(rng):
    q, k, v = _qkv(rng, 1, 64, 4, 2, 8)
    pos = jnp.arange(64)
    a = attention_chunked(q, k, v, q_pos=pos, k_pos=pos, chunk=16, unroll=False)
    b = attention_chunked(q, k, v, q_pos=pos, k_pos=pos, chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("window,chunk", [(8, 8), (8, 16), (16, 16)])
def test_swa_block_matches_naive(rng, window, chunk):
    q, k, v = _qkv(rng, 2, 64, 4, 2, 16)
    pos = jnp.arange(64)
    ref = attention_naive(q, k, v, q_pos=pos, k_pos=pos, causal=True, window=window)
    out = attention_swa_block(q, k, v, q_pos=pos, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_prefix_lm_mask(rng):
    """paligemma: prefix positions attend bidirectionally."""
    q, k, v = _qkv(rng, 1, 32, 4, 2, 8)
    pos = jnp.arange(32)
    out = attention_naive(q, k, v, q_pos=pos, k_pos=pos, causal=True, prefix=8)
    # query 0 (inside prefix) must see key 7 (also prefix, "future")
    out_nc = attention_naive(q, k, v, q_pos=pos, k_pos=pos, causal=True, prefix=0)
    assert not np.allclose(np.asarray(out[:, 0]), np.asarray(out_nc[:, 0]))


def test_ring_decode_matches_linear_cache(rng):
    """Ring (slot = pos % w) equals a plain cache while pos < w, and applies
    the window once wrapped."""
    b, h, kh, d, w = 1, 4, 2, 8, 16
    q = jnp.asarray(rng.randn(b, 1, h, d), jnp.float32)
    kc = jnp.zeros((b, w, kh, d), jnp.float32)
    vc = jnp.zeros((b, w, kh, d), jnp.float32)
    ks, vs = [], []
    outs_ring = []
    for pos in range(2 * w):
        nk = jnp.asarray(rng.randn(b, 1, kh, d), jnp.float32)
        nv = jnp.asarray(rng.randn(b, 1, kh, d), jnp.float32)
        ks.append(nk)
        vs.append(nv)
        o, kc, vc = ring_decode_attention(q, kc, vc, nk, nv, pos, w)
        outs_ring.append(o)
    # reference: full attention over the last w tokens
    K = jnp.concatenate(ks, axis=1)
    V = jnp.concatenate(vs, axis=1)
    for pos in (w - 1, w, 2 * w - 1):
        lo = max(pos - w + 1, 0)
        kw, vw = K[:, lo:pos + 1], V[:, lo:pos + 1]
        pad = w - kw.shape[1]
        ref, _, _ = decode_attention(
            NULL_CTX, q,
            jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.zeros_like(kw[:, :1]), jnp.zeros_like(vw[:, :1]),
            jnp.asarray(kw.shape[1] - 1), update=False)
        np.testing.assert_allclose(np.asarray(outs_ring[pos]), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_row_update_mode_matches_full(rng):
    from repro.models.common import _decode_core
    import functools
    b, s, kh, h, d = 2, 32, 2, 4, 8
    q = jnp.asarray(rng.randn(b, 1, h, d), jnp.float32)
    kc = jnp.asarray(rng.randn(b, s, kh, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, s, kh, d), jnp.float32)
    nk = jnp.asarray(rng.randn(b, 1, kh, d), jnp.float32)
    nv = jnp.asarray(rng.randn(b, 1, kh, d), jnp.float32)
    import jax
    with jax.disable_jit():  # axis_index needs a mesh; emulate single shard
        pass
    # single-shard comparison via the public API
    from repro.models.common import _single_decode
    a = _single_decode(q, kc, vc, nk, nv, 7)
    # row mode only differs inside shard_map; the math is dus either way
    np.testing.assert_allclose(np.asarray(a[1][0, 7]), np.asarray(nk[0, 0]))

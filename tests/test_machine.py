"""MachineModel layer + always-on telemetry (ISSUE-6).

The machine profile is the paper's latency dial as a runtime input: one
frozen model per named machine, selected process-wide, with every depth
solve / roofline term / feedback-store key derived from the active profile.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core import machine as machine_mod
from repro.core import schedule
from repro.core.machine import (
    MACHINES,
    MachineModel,
    get_machine,
    machine_profile,
    profile_names,
    set_machine,
)
from repro.kernels.coro_gather.coro_gather import row_gather_spec
from repro.kernels.coro_scatter_add.coro_scatter_add import scatter_add_spec
from repro.kernels.decode_attention.decode_attention import decode_spec
from repro.kernels.moe_gmm.moe_gmm import gmm_spec
from repro.kernels.ssd_scan.ssd_scan import ssd_spec
from repro.kernels.stream_copy.stream_copy import triad_spec

# one representative spec per kernel family (the shapes the benches use)
FAMILY_SPECS = {
    "row_gather": lambda: row_gather_spec(8, 128, jnp.float32),
    "scatter_add": lambda: scatter_add_spec(8, 128, jnp.float32),
    "decode": lambda: decode_spec(128, 8, 12, 128, jnp.float32),
    "gmm": lambda: gmm_spec(64, 512, 128, jnp.float32, f_total=2048),
    "ssd": lambda: ssd_spec(64, 8, 64, 128, jnp.float32, seq_len=2048),
    "triad": lambda: triad_spec(128, 512, jnp.float32),
}


# ------------------------------------------------------------ profile table


def test_profile_table_contents():
    for name in ("v5e", "v5e-far-200ns", "v5e-far-800ns", "cpu-interpret",
                 "nh-g"):
        assert name in MACHINES
        assert MACHINES[name].name == name
    assert set(profile_names()) == set(MACHINES)


def test_far_profiles_dial_latency_only():
    base = machine_profile("v5e")
    far2 = machine_profile("v5e-far-200ns")
    far8 = machine_profile("v5e-far-800ns")
    assert far2.hbm_latency_s == pytest.approx(base.hbm_latency_s + 200e-9)
    assert far8.hbm_latency_s == pytest.approx(base.hbm_latency_s + 800e-9)
    # bandwidth held fixed: the dial isolates latency tolerance
    assert far2.hbm_bw == base.hbm_bw == far8.hbm_bw
    # the far AMU provisions more request slots than local HBM's DMA engine
    assert far8.request_slots > base.request_slots


def test_model_is_frozen():
    with pytest.raises(Exception):
        machine_profile("v5e").hbm_bw = 1.0


def test_unknown_profile_raises_with_known_names():
    with pytest.raises(KeyError, match="v5e"):
        machine_profile("tpu9000")


def test_set_machine_by_name_and_model_and_reset():
    assert get_machine().name == "v5e"
    assert set_machine("v5e-far-800ns").name == "v5e-far-800ns"
    assert get_machine().name == "v5e-far-800ns"
    custom = machine_profile("v5e").replace(name="custom", hbm_latency_s=1e-6)
    assert set_machine(custom) is custom
    assert get_machine().hbm_latency_s == 1e-6
    assert set_machine(None).name == "v5e"


def test_env_var_selects_profile(monkeypatch):
    monkeypatch.setenv(machine_mod.MACHINE_ENV, "v5e-far-800ns")
    assert set_machine(None).name == "v5e-far-800ns"
    monkeypatch.setenv(machine_mod.MACHINE_ENV, "nope")
    with pytest.raises(KeyError):
        set_machine(None)
    monkeypatch.delenv(machine_mod.MACHINE_ENV)
    set_machine(None)


def test_default_interpret_follows_backend():
    set_machine("cpu-interpret")
    assert machine_mod.default_interpret() is True


# --------------------------------------------------- legacy constant aliases


def test_aliases_track_active_profile():
    assert schedule.REQUEST_SLOTS == 64
    assert machine_mod.PEAK_FLOPS == machine_profile("v5e").peak_flops
    set_machine("v5e-far-800ns")
    assert schedule.REQUEST_SLOTS == 256
    assert schedule.HBM_LATENCY_S == pytest.approx(1500e-9)
    assert machine_mod.VMEM_BYTES == 128 * 1024 * 1024
    from repro import roofline
    assert roofline.HBM_BW == machine_profile("v5e-far-800ns").hbm_bw


# -------------------------------------------------- the latency-dial sweep


@pytest.mark.parametrize("family", sorted(FAMILY_SPECS))
def test_far_latency_solves_strictly_deeper(family):
    """REPRO_MACHINE=v5e-far-800ns must pipeline deeper than v5e for EVERY
    kernel family, and depth must be monotone along the 200ns->800ns dial."""
    spec = FAMILY_SPECS[family]()
    depths = {
        name: autotune.choose_depth(spec.profile(),
                                    machine=machine_profile(name),
                                    vars=spec.all_vars())
        for name in ("v5e", "v5e-far-200ns", "v5e-far-800ns")
    }
    assert depths["v5e"] <= depths["v5e-far-200ns"] <= depths["v5e-far-800ns"]
    assert depths["v5e-far-800ns"] > depths["v5e"]


@pytest.mark.parametrize("family", sorted(FAMILY_SPECS))
def test_env_profile_reaches_depth_solver(family, monkeypatch):
    """The full path: env var -> set_machine(None) -> choose_depth."""
    spec = FAMILY_SPECS[family]()

    def solve():
        return autotune.choose_depth(spec.profile(), vars=spec.all_vars())

    monkeypatch.setenv(machine_mod.MACHINE_ENV, "v5e")
    set_machine(None)
    d_near = solve()
    monkeypatch.setenv(machine_mod.MACHINE_ENV, "v5e-far-800ns")
    set_machine(None)
    d_far = solve()
    assert d_far > d_near


# ---------------------------------------------- adaptive re-solve feedback


def test_samples_flip_static_to_adaptive_and_depths_track_latency():
    spec = FAMILY_SPECS["triad"]()
    prof, vars_ = spec.profile(), spec.all_vars()

    d_static = autotune.choose_depth(prof, kernel="stream_triad", vars=vars_)
    assert autotune.telemetry_summary()["kernels"]["stream_triad"]["mode"] \
        == "static"

    for s in np.full(32, 2e-6):
        autotune.record_transfer("stream_triad", float(s))
    d_near = autotune.choose_depth(prof, kernel="stream_triad", vars=vars_)
    assert autotune.telemetry_summary()["kernels"]["stream_triad"]["mode"] \
        == "adaptive"

    autotune.clear_samples("stream_triad")
    for s in np.full(32, 8e-6):
        autotune.record_transfer("stream_triad", float(s))
    d_far = autotune.choose_depth(prof, kernel="stream_triad", vars=vars_)

    # observed 2us tail already exceeds the modelled 700ns; 8us more so
    assert d_static < d_near < d_far
    assert autotune.last_choice("stream_triad") == d_far


def test_machine_switch_invalidates_samples():
    spec = FAMILY_SPECS["gmm"]()
    prof, vars_ = spec.profile(), spec.all_vars()
    autotune.record_transfer("moe_gmm", 5e-6)
    assert autotune.transfer_samples("moe_gmm")
    d_v5e = autotune.choose_depth(prof, kernel="moe_gmm", vars=vars_)
    assert autotune.telemetry_summary()["kernels"]["moe_gmm"]["mode"] \
        == "adaptive"

    set_machine("v5e-far-800ns")
    # the other profile's samples are invisible: static solve again
    assert autotune.transfer_samples("moe_gmm") == []
    autotune.choose_depth(prof, kernel="moe_gmm", vars=vars_)
    assert autotune.telemetry_summary()["kernels"]["moe_gmm"]["mode"] \
        == "static"

    set_machine("v5e")
    assert len(autotune.transfer_samples("moe_gmm")) == 1
    assert autotune.choose_depth(prof, kernel="moe_gmm", vars=vars_) == d_v5e


def test_clear_samples_also_clears_last_choice():
    spec = FAMILY_SPECS["row_gather"]()
    autotune.choose_depth(spec.profile(), kernel="row_gather",
                          vars=spec.all_vars())
    assert autotune.last_choice("row_gather") is not None
    autotune.clear_samples("row_gather")
    assert autotune.last_choice("row_gather") is None
    assert "row_gather" not in autotune.telemetry_summary()["kernels"]


# ------------------------------------------------------ always-on telemetry


def test_kernel_entry_point_feeds_telemetry(rng):
    """Running any kernel entry point twice populates telemetry_summary()
    without the caller ever touching record_transfer — run one is compile
    warmup (dropped), run two records wall-clock/tiles."""
    from repro.kernels.coro_gather.ops import coro_gather

    table = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, 32), jnp.int32)

    coro_gather(table, idx, interpret=True)
    assert autotune.transfer_samples("row_gather") == []  # warmup dropped
    coro_gather(table, idx, interpret=True)

    summ = autotune.telemetry_summary()
    assert summ["machine"] == "v5e"
    entry = summ["kernels"]["row_gather"]
    assert entry["samples"] >= 1
    assert entry["depth"] is not None
    assert entry["p99_us"] >= entry["p50_us"] > 0


def test_telemetry_switch_disables_recording(rng):
    from repro.kernels.coro_gather.ops import coro_gather

    table = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, 32), jnp.int32)
    autotune.set_telemetry(False)
    try:
        coro_gather(table, idx, interpret=True)
        coro_gather(table, idx, interpret=True)
        assert autotune.transfer_samples("row_gather") == []
    finally:
        autotune.set_telemetry(True)


def test_sample_ring_is_bounded():
    for i in range(autotune.MAX_SAMPLES_PER_KERNEL + 40):
        autotune.record_transfer("k", 1e-6 + i * 1e-9)
    xs = autotune.transfer_samples("k")
    assert len(xs) == autotune.MAX_SAMPLES_PER_KERNEL
    # oldest samples were evicted
    assert min(xs) > 1e-6 + 39e-9

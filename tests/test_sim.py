"""The paper's numeric claims hold in the calibrated model (±15%),
plus structural invariants (seeded parametrize sweeps)."""
import statistics

import pytest

from repro.core import sim


def within(x, target, tol=0.15):
    return abs(x - target) / target <= tol


# ---------------------------------------------------------- headline claims


def test_full_system_average_200ns():
    assert within(sim.average_speedup("coroamu-full", latency_ns=200), 3.39)


def test_full_system_average_800ns():
    assert within(sim.average_speedup("coroamu-full", latency_ns=800), 4.87)


def test_gups_peak_speedups():
    g = sim.BENCHES["GUPS"]
    assert within(sim.speedup("coroamu-full", g, latency_ns=200), 29.0)
    assert within(sim.speedup("coroamu-full", g, latency_ns=800), 59.8)


def test_x86_compiler_study():
    for lat, sota, ours in ((90, 1.40, 2.11), (130, 2.01, 2.78)):
        co = sim.average_speedup("coroutine", latency_ns=lat, ua=sim.SKYLAKE,
                                 tune_coros=True)
        cs = sim.average_speedup("coroamu-s", latency_ns=lat, ua=sim.SKYLAKE,
                                 tune_coros=True)
        assert within(co, sota), (lat, co)
        assert within(cs, ours), (lat, cs)
        assert cs > co  # the compiler beats hand-written coroutines


def test_coroamu_d_mispredict_over_15_percent():
    ms = statistics.mean(
        sim.simulate("coroamu-d", b, latency_ns=200, n_coros=96).breakdown["mispredict"]
        for b in sim.BENCHES.values())
    assert ms > 0.15


def test_bafin_removes_mispredicts_and_helps():
    for b in sim.BENCHES.values():
        d = sim.simulate("coroamu-d", b, latency_ns=200, n_coros=96)
        f = sim.simulate("coroamu-full", b, latency_ns=200, n_coros=96,
                         ctx_opt=False, coalesce=False)
        assert f.breakdown["mispredict"] == 0.0
        assert f.cycles_per_iter <= d.cycles_per_iter


def test_mlp_claims():
    g = sim.BENCHES["GUPS"]
    assert sim.simulate("serial", g, latency_ns=800).mlp < 5
    assert sim.simulate("coroamu-s", g, latency_ns=800, n_coros=96).mlp < 20
    assert sim.simulate("coroamu-full", g, latency_ns=800, n_coros=96).mlp >= 50


def test_instruction_expansion_ordering():
    e = sim.EXPANSION
    assert e["coroamu-s"] > e["coroamu-d"] > e["coroamu-full"] > 1.0
    assert e["coroamu-s"] == 6.70 and e["coroamu-d"] == 5.98 and e["coroamu-full"] == 3.91


def test_compiler_opts_help_where_paper_says():
    """Fig. 15: context opt helps GUPS/IS/HJ; aggregation helps mcf/HJ/lbm/STREAM."""
    for name in ("GUPS", "IS", "HJ"):
        b = sim.BENCHES[name]
        base = sim.simulate("coroamu-full", b, latency_ns=100, n_coros=96,
                            ctx_opt=False, coalesce=False).cycles_per_iter
        opt = sim.simulate("coroamu-full", b, latency_ns=100, n_coros=96,
                           ctx_opt=True, coalesce=False).cycles_per_iter
        assert opt <= base
    for name in ("mcf", "HJ", "lbm", "STREAM"):
        b = sim.BENCHES[name]
        base = sim.simulate("coroamu-full", b, latency_ns=100, n_coros=96,
                            ctx_opt=True, coalesce=False).cycles_per_iter
        agg = sim.simulate("coroamu-full", b, latency_ns=100, n_coros=96,
                           ctx_opt=True, coalesce=True).cycles_per_iter
        assert agg < base


# ------------------------------------------------------------- invariants


@pytest.mark.parametrize("lat,n", [(100.0, 2), (237.5, 96), (1000.0, 512)])
@pytest.mark.parametrize("bench", sorted(sim.BENCHES))
@pytest.mark.parametrize("variant", sim.VARIANTS)
def test_sim_invariants(lat, n, bench, variant):
    r = sim.simulate(variant, sim.BENCHES[bench], latency_ns=lat, n_coros=n)
    assert r.cycles_per_iter > 0
    assert 0 <= r.mlp <= max(n, sim.NH_G.amu_inflight, 64) + 1
    assert all(v >= 0 for v in r.breakdown.values())


@pytest.mark.parametrize("bench", sorted(sim.BENCHES))
def test_serial_monotone_in_latency(bench):
    b = sim.BENCHES[bench]
    ts = [sim.simulate("serial", b, latency_ns=l).cycles_per_iter
          for l in (100, 200, 400, 800)]
    assert ts == sorted(ts)

"""End-to-end behaviour: training learns, checkpoints resume exactly,
failures recover, serving generates — the paper's system integrated."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime.fault_tolerance import run_with_restarts
from repro.runtime.train_loop import train


def _tiny(arch="granite-3-2b"):
    return get_config(arch).reduced().replace(vocab=64, n_layers=2)


def test_training_reduces_loss_toward_entropy():
    cfg = _tiny()
    model = build_model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, branching=2)
    rep = train(model, steps=30, data_cfg=data,
                opt=AdamWConfig(lr=5e-3, total_steps=30, warmup_steps=3))
    first, last = min(rep.losses), max(rep.losses)
    assert rep.losses[last] < rep.losses[first] - 0.3


def test_checkpoint_resume_is_exact(tmp_path):
    cfg = _tiny()
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    opt = AdamWConfig(lr=1e-3, total_steps=12, warmup_steps=2)
    # uninterrupted run
    m1 = build_model(cfg)
    r1 = train(m1, steps=12, data_cfg=data, opt=opt, seed=7)
    # interrupted at 6, resumed (fresh model object, state from disk)
    m2 = build_model(cfg)
    train(m2, steps=6, data_cfg=data, opt=opt, seed=7,
          ckpt_dir=tmp_path, ckpt_every=6)
    m3 = build_model(cfg)
    r3 = train(m3, steps=12, data_cfg=data, opt=opt, seed=7,
               ckpt_dir=tmp_path, ckpt_every=6)
    assert r3.resumed_from == 6
    last = max(r1.losses)
    np.testing.assert_allclose(r1.losses[last], r3.losses[last], rtol=1e-4)


def test_injected_failure_recovers_via_supervisor(tmp_path):
    cfg = _tiny()
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    opt = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=2)
    attempts = {"n": 0}

    def loop():
        attempts["n"] += 1
        fail = 5 if attempts["n"] == 1 else None  # crash only on first attempt
        train(build_model(cfg), steps=10, data_cfg=data, opt=opt,
              ckpt_dir=tmp_path, ckpt_every=2, fail_at_step=fail)

    rep = run_with_restarts(loop, restore_fn=lambda: None, max_restarts=2)
    assert rep.completed and rep.restarts == 1
    from repro.checkpointing.checkpoint import latest_step
    assert latest_step(tmp_path) == 10


def test_grad_compression_trains():
    cfg = _tiny()
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, branching=2)
    rep = train(build_model(cfg), steps=20, data_cfg=data,
                opt=AdamWConfig(lr=5e-3, total_steps=20, warmup_steps=2),
                compress_grads=True)
    first, last = min(rep.losses), max(rep.losses)
    assert rep.losses[last] < rep.losses[first]


def test_grad_accumulation_matches_large_batch():
    cfg = _tiny().replace(dtype="float32")
    model = build_model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
    opt = AdamWConfig(lr=1e-3, total_steps=3, warmup_steps=1, grad_clip=0.0)
    r_full = train(model, steps=3, data_cfg=data, opt=opt, seed=3)
    r_acc = train(model, steps=3, data_cfg=data, opt=opt, seed=3, accum=4)
    last = max(r_full.losses)
    np.testing.assert_allclose(r_full.losses[last], r_acc.losses[last], rtol=1e-3)


def test_serve_generates_tokens():
    from repro.launch.serve import serve
    cfg = _tiny("yi-6b")
    stats = serve(cfg, batch=2, prompt_len=16, gen=4)
    assert stats["generated_shape"] == (2, 4)

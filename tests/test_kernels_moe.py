"""Grouped-matmul kernel vs einsum oracle; MoE layer backends agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.models.moe import (
    _expert_ffn,
    _gmm_eligible,
    moe_dense,
    moe_param_specs,
    router_topk,
)
from repro.models import params as pm


@pytest.mark.parametrize("e,c,dm,f,ft", [(2, 8, 16, 128, 128), (4, 16, 32, 256, 128)])
def test_gmm_matches_ref(rng, e, c, dm, f, ft):
    t = jnp.asarray(rng.randn(e, c, dm), jnp.float32)
    w = jnp.asarray(rng.randn(e, dm, f), jnp.float32)
    np.testing.assert_allclose(np.asarray(moe_gmm(t, w, f_tile=ft)),
                               np.asarray(gmm_ref(t, w)), rtol=1e-4, atol=1e-4)


def test_router_topk_normalized(rng):
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    p = pm.initialize(jax.random.PRNGKey(0), moe_param_specs(cfg))
    x = jnp.asarray(rng.randn(32, cfg.d_model), jnp.float32)
    gates, experts, aux = router_topk(x, p["router"], cfg.top_k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert int(experts.max()) < cfg.n_experts
    assert float(aux) > 0.0


def test_expert_ffn_gmm_backend_matches_dense(rng):
    """The streamed-weight gmm backend (TPU dispatch path, run here in
    interpret mode) must agree with the jnp-einsum twin."""
    e, c, dm, f = 2, 8, 128, 256
    xs = jnp.asarray(rng.randn(e, c, dm), jnp.float32)
    wg = jnp.asarray(rng.randn(e, dm, f) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.randn(e, dm, f) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.randn(e, f, dm) * 0.1, jnp.float32)
    got = _expert_ffn(xs, wg, wu, wd, use_gmm=True)
    want = _expert_ffn(xs, wg, wu, wd, use_gmm=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_expert_ffn_gmm_gating(rng):
    """Shapes the kernel can't tile (or mismatched expert batching) fall
    back to the einsum twin instead of asserting inside the kernel."""
    e, c, dm, f = 2, 4, 96, 96  # not divisible by the 128 f_tile
    xs = jnp.asarray(rng.randn(e, c, dm), jnp.float32)
    wg = jnp.asarray(rng.randn(e, dm, f), jnp.float32)
    wu = jnp.asarray(rng.randn(e, dm, f), jnp.float32)
    wd = jnp.asarray(rng.randn(e, f, dm), jnp.float32)
    assert not _gmm_eligible(xs, wg, wu, wd)
    assert not _gmm_eligible(xs[:1], jnp.zeros((4, dm, 128)),
                             jnp.zeros((4, dm, 128)), jnp.zeros((4, 128, dm)))
    out = _expert_ffn(xs, wg, wu, wd, use_gmm=True)  # falls back, no raise
    assert out.shape == (e, c, dm)


def test_moe_dense_combines_topk_only(rng):
    cfg = get_config("granite-moe-1b-a400m").reduced()
    p = pm.initialize(jax.random.PRNGKey(1), moe_param_specs(cfg))
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model), jnp.float32)
    y, aux = moe_dense(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()

"""Grouped-matmul kernel vs einsum oracle; MoE layer backends agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.models.moe import moe_dense, moe_param_specs, router_topk
from repro.models import params as pm


@pytest.mark.parametrize("e,c,dm,f,ft", [(2, 8, 16, 128, 128), (4, 16, 32, 256, 128)])
def test_gmm_matches_ref(rng, e, c, dm, f, ft):
    t = jnp.asarray(rng.randn(e, c, dm), jnp.float32)
    w = jnp.asarray(rng.randn(e, dm, f), jnp.float32)
    np.testing.assert_allclose(np.asarray(moe_gmm(t, w, f_tile=ft)),
                               np.asarray(gmm_ref(t, w)), rtol=1e-4, atol=1e-4)


def test_router_topk_normalized(rng):
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    p = pm.initialize(jax.random.PRNGKey(0), moe_param_specs(cfg))
    x = jnp.asarray(rng.randn(32, cfg.d_model), jnp.float32)
    gates, experts, aux = router_topk(x, p["router"], cfg.top_k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert int(experts.max()) < cfg.n_experts
    assert float(aux) > 0.0


def test_moe_dense_combines_topk_only(rng):
    cfg = get_config("granite-moe-1b-a400m").reduced()
    p = pm.initialize(jax.random.PRNGKey(1), moe_param_specs(cfg))
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model), jnp.float32)
    y, aux = moe_dense(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()

"""Shared-prefix KV cache + chunked prefill (ISSUE-7): radix index unit
tests, copy-on-write lifecycle, jit-cache bounds, budgeted-round fairness,
and the end-to-end acceptance scenario (shared prefixes dedup physical
pages without changing a single emitted token)."""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import autotune
from repro.models import build_model
from repro.serve import (
    ContinuousBatchingScheduler,
    KVPager,
    PagedServingEngine,
    PrefixCache,
    Request,
    bucket_len,
)

# ------------------------------------------------------------- radix index


def _pager_cache(num_blocks=16, blk=4):
    pager = KVPager(num_blocks=num_blocks, block_size=blk)
    return pager, PrefixCache(pager)


def test_prefix_match_walks_full_blocks():
    pager, cache = _pager_cache()
    table = pager.alloc(0, 12)  # 3 blocks
    toks = list(range(100, 112))
    assert cache.insert(toks, table) == 3
    m = cache.match(toks + [7, 8])
    assert m.hit and m.n_tokens == 12 and m.blocks == table
    # a diverging prompt matches only the common full blocks
    m = cache.match(toks[:8] + [1, 2, 3, 4])
    assert m.n_tokens == 8 and m.blocks == table[:2]
    assert cache.match([9, 9, 9, 9]).hit is False
    pager.check_invariants(extra_refs=cache.block_refs())


def test_prefix_match_shares_partial_block_on_lcp():
    """Divergence inside a block still shares that page (n_tokens lands
    mid-block) — the requester CoW-forks before writing its own rows."""
    pager, cache = _pager_cache()
    table = pager.alloc(0, 8)
    toks = list(range(10, 18))
    cache.insert(toks, table)
    m = cache.match(toks[:6] + [1, 2, 3])  # diverges 2 tokens into block 1
    assert m.n_tokens == 6 and m.blocks == table
    pager.check_invariants(extra_refs=cache.block_refs())


def test_prefix_match_never_covers_whole_prompt():
    """>=1 token is always left to prefill so the engine has logits to
    sample the first output from; capping can drop the tail page."""
    pager, cache = _pager_cache()
    table = pager.alloc(0, 8)
    toks = list(range(20, 28))
    cache.insert(toks, table)
    m = cache.match(toks)  # full coverage must be capped to 7
    assert m.n_tokens == 7 and m.blocks == table
    m = cache.match(toks[:4])  # capped to 3: the only page is dropped? no -
    assert m.n_tokens == 3 and m.blocks == table[:1]
    m = cache.match(toks[:1])
    assert not m.hit  # capping to 0 tokens is a miss


def test_prefix_insert_is_idempotent_and_refcounts_once():
    pager, cache = _pager_cache()
    t0 = pager.alloc(0, 8)
    toks = list(range(30, 38))
    assert cache.insert(toks, t0) == 2
    assert cache.insert(toks, t0) == 0  # re-insert: no double ref
    assert pager.refcount(t0[0]) == 2   # owner + cache, exactly
    # a second request with its own duplicate pages doesn't displace them
    t1 = pager.alloc(1, 8)
    assert cache.insert(toks, t1) == 0
    pager.check_invariants(extra_refs=cache.block_refs())
    pager.free(0)
    pager.free(1)
    pager.check_invariants(extra_refs=cache.block_refs())
    assert len(cache) == 2  # cached pages outlive their owner


def test_prefix_evict_lru_leaves_and_protect():
    pager, cache = _pager_cache()
    t0 = pager.alloc(0, 16)  # 4 blocks, one chain
    cache.insert(list(range(40, 56)), t0)
    pager.free(0)
    t1 = pager.alloc(1, 4)
    cache.insert([1, 2, 3, 4], t1)
    pager.free(1)
    cache.match(list(range(40, 56)))  # refresh the chain's recency
    # only leaves are candidates; the [1,2,3,4] leaf is now the LRU one
    assert cache.evict(1) == [t1[0]]
    # protected pages are skipped
    assert cache.evict(1, protect=frozenset(t0)) == []
    evicted = cache.evict(10)
    assert evicted == list(reversed(t0))  # leaf-first up the chain
    pager.check_invariants()
    assert pager.free_blocks == pager.num_blocks


def _evict_scan_reference(cache, n_blocks, protect=frozenset()):
    """The pre-heap O(nodes x blocks) eviction, kept verbatim as the oracle
    for the lazy-heap rewrite (ISSUE-9 satellite): min last_hit under
    strict <, ties broken by `_by_block` iteration (= node creation) order,
    skipping interior / protected / still-referenced pages."""
    evicted = []
    while len(evicted) < n_blocks:
        best = None
        for node in cache._by_block.values():
            if node.children or node.block in protect:
                continue
            if cache.pager.refcount(node.block) != 1:
                continue
            if best is None or node.last_hit < best.last_hit:
                best = node
        if best is None:
            break
        siblings = best.parent.children if best.parent else cache._children
        del siblings[best.tokens]
        del cache._by_block[best.block]
        cache.pager.release(best.block)
        evicted.append(best.block)
        cache.evictions += 1
    return evicted


def _parity_ops(n=140, seed=321):
    """A deterministic alloc/free/match/evict schedule over a tiny vocab so
    prefixes collide and partially diverge all over the tree."""
    rng = np.random.RandomState(seed)
    header = [int(v) for v in rng.choice(8, size=12)]
    ops, rid = [], 0
    for _ in range(n):
        r = rng.rand()
        toks = [int(v) for v in rng.choice(8, size=int(rng.randint(4, 20)))]
        if rng.rand() < 0.5:
            k = min(len(toks) - 1, 8)
            toks[:k] = header[:k]
        if r < 0.45:
            ops.append(("alloc", rid, toks))
            rid += 1
        elif r < 0.62 and rid:
            ops.append(("free", int(rng.randint(rid))))
        elif r < 0.8:
            ops.append(("match", toks))
        else:
            ops.append(("evict", int(rng.randint(1, 5)),
                        int(rng.randint(3))))
    return ops


def _apply_parity_ops(ops, evict_fn):
    pager = KVPager(num_blocks=32, block_size=4)
    cache = PrefixCache(pager)
    live = set()
    results = []
    for op in ops:
        if op[0] == "alloc":
            _, rid, toks = op
            if pager.can_alloc(len(toks)):
                pager.alloc(rid, len(toks))
                live.add(rid)
                cache.insert(toks, pager.block_table(rid))
        elif op[0] == "free":
            if op[1] in live:
                pager.free(op[1])
                live.remove(op[1])
        elif op[0] == "match":
            cache.match(op[1])
        else:
            _, n, mod = op
            protect = frozenset(b for b in cache._by_block if b % 3 == mod)
            results.append(tuple(evict_fn(cache, n, protect)))
        pager.check_invariants(cache.block_refs())
    for rid in sorted(live):
        pager.free(rid)
    results.append(tuple(evict_fn(cache, 99, frozenset())))
    pager.check_invariants(cache.block_refs())
    return results, sorted(cache._by_block)


def test_evict_heap_matches_reference_scan_order():
    """Satellite 3 parity: the lazy-heap eviction must pick the exact pages
    in the exact order the old full-scan did, through an interleaved
    randomized schedule (including mid-schedule protected evictions and a
    final drain)."""
    ops = _parity_ops()
    heap_res, heap_left = _apply_parity_ops(
        ops, lambda c, n, p: c.evict(n, p))
    ref_res, ref_left = _apply_parity_ops(ops, _evict_scan_reference)
    assert heap_res == ref_res
    assert heap_left == ref_left
    assert any(any(r) for r in heap_res)  # the schedule actually evicted


def test_prefix_evict_skips_pages_still_in_live_tables():
    pager, cache = _pager_cache()
    t0 = pager.alloc(0, 8)
    cache.insert(list(range(60, 68)), t0)
    assert cache.evict(5) == []  # request 0 still reads both pages
    pager.free(0)
    assert len(cache.evict(5)) == 2
    pager.check_invariants()


# ------------------------------------------------- pow2 jit-cache bounding


def test_bucket_len_pow2_with_floor():
    assert [bucket_len(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert bucket_len(3, floor=16) == 16
    assert bucket_len(33, floor=16) == 64
    with pytest.raises(ValueError):
        bucket_len(0)


def test_engine_prefill_jit_cache_is_logarithmic():
    """Satellite 1: serving every prompt length 1..max_len compiles at most
    ~log2(max_len) chunk programs, not one per length."""
    cfg = get_config("yi-6b").reduced().replace(dtype="float32",
                                                param_dtype="float32")
    rng = np.random.default_rng(5)
    max_len = 17
    eng = PagedServingEngine(cfg, block_size=4, num_blocks=32,
                             max_in_flight=2, prefill_chunk=64)
    for n in range(1, max_len + 1):
        eng.submit(rng.integers(0, cfg.vocab, n), max_new_tokens=2)
    eng.run()
    assert len(eng._prefill_fns) <= math.ceil(math.log2(max_len)) + 1


# --------------------------------------------------------- budgeted rounds


def _req(rid, prompt_len, max_new=4):
    return Request(rid=rid, prompt=list(range(1, prompt_len + 1)),
                   max_new_tokens=max_new)


def test_plan_round_respects_token_budget():
    """Decodes are never starved; prefill chunks only spend what's left."""
    pager = KVPager(num_blocks=32, block_size=4)
    sched = ContinuousBatchingScheduler(pager, max_in_flight=8, token_budget=6)
    decoders = [_req(i, 4) for i in range(3)]
    long = _req(9, 40)
    for r in decoders + [long]:
        sched.submit(r)
    for r in sched.admit():
        if r is not long:
            r.prefill_pos = len(r.context)
            sched.promote(r)
    decodes, plans = sched.plan_round(chunk=16)
    assert decodes == decoders
    # 6-token budget minus 3 decodes leaves 3 prefill tokens (chunk caps 16)
    assert plans == [(long, 3)]
    long.prefill_pos += 3
    decodes, plans = sched.plan_round(chunk=2)
    assert plans == [(long, 2)]  # chunk caps below the leftover budget
    # a saturated budget plans zero prefill
    sched.token_budget = 3
    assert sched.plan_round(chunk=16) == (decoders, [])


def test_plan_round_orders_prefill_oldest_first():
    pager = KVPager(num_blocks=32, block_size=4)
    sched = ContinuousBatchingScheduler(pager, max_in_flight=8, token_budget=8)
    a, b = _req(0, 20), _req(1, 20)
    sched.submit(a)
    sched.submit(b)
    sched.admit()
    _, plans = sched.plan_round(chunk=6)
    assert plans == [(a, 6), (b, 2)]  # oldest drains first, b gets the rest


def _f32_cfg():
    return get_config("yi-6b").reduced().replace(dtype="float32",
                                                 param_dtype="float32")


def test_chunked_prefill_does_not_starve_decodes():
    """Satellite 3: a long prompt admitted mid-stream stalls in-flight
    decode gaps far less when it trickles through chunks than when it lands
    as one monolithic prefill (same engine path, huge chunk)."""
    cfg = _f32_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    short = rng.integers(0, cfg.vocab, 4)
    long = rng.integers(0, cfg.vocab, 96)

    def run(chunk):
        eng = PagedServingEngine(cfg, block_size=4, num_blocks=64,
                                 params=params, max_in_flight=2,
                                 prefill_chunk=chunk, prefix_cache=False)
        # warm every jit bucket this workload will touch, then measure
        eng.submit(short, max_new_tokens=24)
        eng.submit(long, max_new_tokens=2)
        eng.run()
        eng.tbt_s.clear()
        eng.submit(short, max_new_tokens=24)
        eng.step_round()  # the short request starts decoding alone...
        eng.submit(long, max_new_tokens=2)  # ...then the long prompt lands
        eng.run()
        return max(eng.tbt_s)

    chunked = run(8)
    monolithic = run(512)
    assert chunked <= monolithic


def test_chunked_prefill_feeds_pipeline_telemetry():
    """Warm prefill chunks land in the `paged_prefill` transfer-feedback
    store (the first observation per tile count is compile warmup)."""
    cfg = _f32_cfg()
    rng = np.random.default_rng(6)
    autotune.set_telemetry(True)
    eng = PagedServingEngine(cfg, block_size=4, num_blocks=32,
                             max_in_flight=1, prefill_chunk=8,
                             prefix_cache=False)
    for _ in range(4):  # identical shapes: same buckets, same tile counts
        eng.submit(rng.integers(0, cfg.vocab, 16), max_new_tokens=2)
    eng.run()
    assert len(autotune.transfer_samples("paged_prefill")) > 0
    assert "paged_prefill" in autotune.telemetry_summary()["kernels"]


# ------------------------------------------------------------- end-to-end


def test_engine_shared_prefix_dedups_pages_token_identical():
    """The acceptance scenario: 8 requests sharing a 3-block prefix, pool
    admissions staggered so the cache is warm after the first. >=7 hit,
    strictly fewer physical pages are allocated than without the cache, and
    every emitted token is identical (greedy parity, float32)."""
    cfg = _f32_cfg()
    rng = np.random.default_rng(7)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    blk = 4
    shared = list(rng.integers(0, cfg.vocab, 3 * blk))
    prompts = [shared + list(rng.integers(0, cfg.vocab, 3 + i % 4))
               for i in range(8)]

    def run(prefix_cache):
        eng = PagedServingEngine(cfg, block_size=blk, num_blocks=48,
                                 params=params, max_in_flight=1,
                                 prefix_cache=prefix_cache)
        rids = [eng.submit(p, max_new_tokens=4) for p in prompts]
        stats = eng.run()  # run() checks refcount invariants at drain
        return [eng.request(r).generated for r in rids], stats

    warm_toks, warm = run(True)
    cold_toks, cold = run(False)
    assert warm_toks == cold_toks
    assert warm["prefix_hits"] >= 7
    assert warm["blocks_allocated"] < cold["blocks_allocated"]
    assert warm["blocks_shared"] >= 7 * 3
    assert warm["prefix_tokens"] >= 7 * len(shared)
    assert cold["prefix_hits"] == 0 and cold["blocks_shared"] == 0


def test_engine_cow_divergence_mid_block():
    """Two prompts diverging inside a block: the second shares the partial
    page, CoW-forks it before writing its own suffix rows, and both emit
    exactly what they emit without any sharing."""
    cfg = _f32_cfg()
    rng = np.random.default_rng(8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shared = list(rng.integers(0, cfg.vocab, 6))  # 1.5 blocks at blk=4
    pa = shared + [11, 22, 33]
    pb = shared + [44, 55, 66]

    def run(prefix_cache):
        eng = PagedServingEngine(cfg, block_size=4, num_blocks=32,
                                 params=params, max_in_flight=1,
                                 prefix_cache=prefix_cache)
        rids = [eng.submit(p, max_new_tokens=4) for p in (pa, pb)]
        stats = eng.run()
        return [eng.request(r).generated for r in rids], stats

    warm_toks, warm = run(True)
    cold_toks, cold = run(False)
    assert warm_toks == cold_toks
    assert warm["cow_forks"] >= 1  # the divergence actually forked a page
    assert warm["prefix_hits"] == 1 and warm["prefix_tokens"] == 6


def test_engine_preempted_request_rehits_its_own_pages():
    """Preemption + prefix cache: the victim's recompute-on-readmit turns
    into a prefix hit on its own surviving cached pages."""
    cfg = _f32_cfg()
    rng = np.random.default_rng(9)
    blk, gen = 4, 6
    plens = [10, 10, 10]
    blocks_per_req = -(-(max(plens) + gen) // blk)
    eng = PagedServingEngine(cfg, block_size=blk,
                             num_blocks=blocks_per_req + 2, max_in_flight=3)
    rids = [eng.submit(rng.integers(0, cfg.vocab, n), max_new_tokens=gen)
            for n in plens]
    stats = eng.run()
    assert stats["completed"] == len(plens)
    for rid in rids:
        assert len(eng.request(rid).generated) == gen

"""SSD-scan kernel vs the sequential recurrence oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref


def _inputs(rng, b, s, nh, p, n, dtype=jnp.float32):
    return (jnp.asarray(rng.randn(b, s, nh, p), dtype),
            jnp.asarray(rng.rand(b, s, nh) * 0.5 + 0.1, dtype),
            jnp.asarray(-np.exp(rng.randn(nh) * 0.3), jnp.float32),
            jnp.asarray(rng.randn(b, s, n), dtype),
            jnp.asarray(rng.randn(b, s, n), dtype))


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_scan_matches_sequential(rng, chunk):
    x, dt, A, B, C = _inputs(rng, 2, 128, 3, 8, 16)
    y, hf = ssd(x, dt, A, B, C, chunk=chunk)
    yr, hr = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("nh,p,n", [(1, 4, 8), (4, 16, 32)])
def test_ssd_scan_shape_sweep(rng, nh, p, n):
    x, dt, A, B, C = _inputs(rng, 1, 64, nh, p, n)
    y, hf = ssd(x, dt, A, B, C, chunk=16)
    yr, hr = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-4, atol=3e-4)

import os
import sys
from pathlib import Path

# NOTE: do NOT set XLA_FLAGS/device-count here — smoke tests and benches run
# on the single real CPU device; only launch/dryrun.py forces 512 devices.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# Parity sentinels on (sampled) for the whole suite unless a test or the CI
# lane overrides — tests are exactly where a silent kernel/twin divergence
# should be caught (DESIGN.md §2.7; production default is off).
os.environ.setdefault("REPRO_PARITY", "sampled")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(autouse=True)
def _reset_machine_and_autotune():
    """Isolate tests from each other's feedback state: clear autotune samples
    (which also clears the guard's config quarantine), re-resolve the machine
    profile from the environment, reset the observability layer, and reset
    the guarded-substrate state — counters, circuit breakers, strict/parity
    modes, injector (tests that call set_machine(...), record_transfer(...),
    obs.set_enabled(...), guard.set_strict(...) or trip a breaker must not
    leak into neighbours)."""
    import repro.obs as obs
    from repro.core import autotune, guard
    from repro.core.machine import set_machine

    autotune.clear_samples()
    set_machine(None)
    obs.reset()
    guard.reset()
    yield
    autotune.clear_samples()
    set_machine(None)
    obs.reset()
    guard.reset()

import os
import sys
from pathlib import Path

# NOTE: do NOT set XLA_FLAGS/device-count here — smoke tests and benches run
# on the single real CPU device; only launch/dryrun.py forces 512 devices.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(autouse=True)
def _reset_machine_and_autotune():
    """Isolate tests from each other's feedback state: clear autotune samples
    and re-resolve the machine profile from the environment (tests that call
    set_machine(...) or record_transfer(...) must not leak into neighbours)."""
    from repro.core import autotune
    from repro.core.machine import set_machine

    autotune.clear_samples()
    set_machine(None)
    yield
    autotune.clear_samples()
    set_machine(None)

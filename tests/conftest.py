import os
import sys
from pathlib import Path

# NOTE: do NOT set XLA_FLAGS/device-count here — smoke tests and benches run
# on the single real CPU device; only launch/dryrun.py forces 512 devices.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)

import os
import sys
from pathlib import Path

# NOTE: do NOT set XLA_FLAGS/device-count here — smoke tests and benches run
# on the single real CPU device; only launch/dryrun.py forces 512 devices.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(autouse=True)
def _reset_machine_and_autotune():
    """Isolate tests from each other's feedback state: clear autotune samples,
    re-resolve the machine profile from the environment, and reset the
    observability layer (tests that call set_machine(...), record_transfer(...)
    or obs.set_enabled(...) must not leak into neighbours)."""
    import repro.obs as obs
    from repro.core import autotune
    from repro.core.machine import set_machine

    autotune.clear_samples()
    set_machine(None)
    obs.reset()
    yield
    autotune.clear_samples()
    set_machine(None)
    obs.reset()

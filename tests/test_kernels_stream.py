"""STREAM-triad kernel vs oracle (load+store pipeline)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.stream_copy.ops import stream_triad
from repro.kernels.stream_copy.ref import triad_ref


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("n,d,rows,depth", [(256, 32, 64, 2), (512, 16, 128, 4)])
def test_triad_matches_ref(rng, dtype, tol, n, d, rows, depth):
    b = jnp.asarray(rng.randn(n, d), dtype)
    c = jnp.asarray(rng.randn(n, d), dtype)
    out = stream_triad(b, c, 3.0, rows=rows, depth=depth)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(triad_ref(b, c, 3.0), np.float32),
                               rtol=tol, atol=tol)

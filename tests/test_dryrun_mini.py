"""Mini dry-run in a subprocess (8 forced host devices; the production
512-device sweep runs the same code via launch/dryrun.py)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_mini_dryrun_cell(tmp_path):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=str(ROOT / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
         "--mesh", "mini", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads((tmp_path / "granite-moe-1b-a400m__decode_32k__mini.json").read_text())
    assert rec["status"] == "ok"
    assert rec["hlo_flops_per_chip"] > 0
    assert rec["terms"]["memory_s"] > 0
    assert rec["dominant"] in ("compute_s", "memory_s", "collective_s")

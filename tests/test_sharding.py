"""Sharding rules: divisibility guards and spec construction (mesh-free)."""
import types

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import REGISTRY
from repro.models import lm
from repro.sharding import ShardingCtx


def fake_mesh(shape, names):
    """Stand-in with .axis_names/.devices.shape — spec() never touches jax."""
    return types.SimpleNamespace(axis_names=names,
                                 devices=np.empty(shape, dtype=object))


CTX = ShardingCtx(mesh=fake_mesh((16, 16), ("data", "model")))
CTX3 = ShardingCtx(mesh=fake_mesh((2, 16, 16), ("pod", "data", "model")))


def test_batch_spans_pod_and_data():
    assert CTX3.spec(("batch", "seq", None), (256, 4096, 1)) == P(("pod", "data"))
    assert CTX.spec(("batch", None), (256, 1)) == P("data")


def test_divisibility_guard_replicates():
    # paligemma kv_heads=1 on a 16-way model axis -> replicated
    assert CTX.spec(("batch", "kv_heads"), (256, 1)) == P("data")
    # granite vocab 49155 is not divisible by 16 -> replicated
    assert CTX.spec(("vocab", "embed"), (49155, 2048)) == P(None, "data")
    # command-r vocab 256000 divides -> sharded
    assert CTX.spec(("vocab", "embed"), (256000, 12288)) == P("model", "data")


def test_mesh_axis_used_once_per_tensor():
    # experts and mlp both map to model; only the first dim takes it
    spec = CTX.spec(("experts", "embed", "mlp"), (128, 2048, 768))
    assert spec == P("model", "data")


def test_missing_mesh_axes_are_dropped():
    ctx = ShardingCtx(mesh=fake_mesh((8,), ("data",)))
    assert ctx.spec(("batch", "heads"), (64, 32)) == P("data")


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_param_specs_all_buildable(arch):
    """Every full-size param gets a legal spec on the production mesh."""
    from repro.models import params as pm
    cfg = REGISTRY[arch]
    specs = pm.partition_specs(lm.param_specs(cfg), CTX)
    import jax
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert isinstance(s, P)

"""Paged-KV serving subsystem: pager invariants, scheduler pressure,
paged decode parity on ragged lengths, engine end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.kernels.decode_attention.ref import paged_decode_attention_ref
from repro.models import build_model
from repro.models import common as mc
from repro.serve import (
    ContinuousBatchingScheduler,
    KVPager,
    PagedServingEngine,
    PoolExhausted,
    Request,
    RequestState,
)
from repro.sharding import NULL_CTX


# ------------------------------------------------------------------- pager


def test_pager_randomized_schedule_no_leak_no_double_own(rng):
    """Blocks stay free-xor-owned through a randomized admit/evict/append
    schedule; the garbage page is never handed out."""
    pager = KVPager(num_blocks=24, block_size=4)
    live = []
    next_rid = 0
    for _ in range(600):
        op = rng.choice(["alloc", "append", "free"])
        if op == "alloc":
            n = int(rng.randint(1, 30))
            if pager.can_alloc(n):
                pager.alloc(next_rid, n)
                live.append(next_rid)
                next_rid += 1
            else:
                with pytest.raises(PoolExhausted):
                    pager.alloc(next_rid, n)
        elif op == "append" and live:
            rid = live[rng.randint(len(live))]
            try:
                pos = pager.append_token(rid)
                assert pos == pager.length(rid) - 1
            except PoolExhausted:
                assert pager.free_blocks == 0
        elif op == "free" and live:
            rid = live.pop(rng.randint(len(live)))
            pager.free(rid)
        pager.check_invariants()
    for rid in live:
        pager.free(rid)
    pager.check_invariants()
    assert pager.free_blocks == pager.num_blocks


def test_pager_failed_alloc_leaves_state_intact():
    pager = KVPager(num_blocks=4, block_size=4)
    pager.alloc(0, 12)  # 3 blocks
    with pytest.raises(PoolExhausted):
        pager.alloc(1, 8)  # needs 2, only 1 free
    pager.check_invariants()
    assert pager.free_blocks == 1
    assert not pager.owns(1)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pager_refcount_fuzz_share_fork_free_evict(seed):
    """Refcount state machine under randomized share/fork/free/evict
    interleavings: every usable block stays free xor owned-by-one xor
    shared-by-many (plus emulated external cache refs), the garbage page is
    never refcounted, and draining everything returns the whole pool."""
    rng = np.random.RandomState(1000 + seed)
    pager = KVPager(num_blocks=16, block_size=4)
    live, next_rid = [], 0
    cache_refs = {}  # emulated prefix-cache references

    for _ in range(500):
        op = rng.choice(["alloc", "append", "share", "evict", "free", "fork"])
        if op == "alloc":
            n = int(rng.randint(1, 20))
            if pager.can_alloc(n):
                pager.alloc(next_rid, n)
                live.append(next_rid)
                next_rid += 1
        elif op == "append" and live:
            rid = live[rng.randint(len(live))]
            try:
                pos = pager.append_token(rid)
                pager.ensure_writable(rid, pos)  # CoW if the page is shared
            except PoolExhausted:
                assert pager.free_blocks == 0
        elif op == "share" and live:
            rid = live[rng.randint(len(live))]
            table = pager.block_table(rid)
            b = table[rng.randint(len(table))]
            pager.share(b)
            cache_refs[b] = cache_refs.get(b, 0) + 1
        elif op == "evict" and cache_refs:
            b = list(cache_refs)[rng.randint(len(cache_refs))]
            pager.release(b)
            cache_refs[b] -= 1
            if cache_refs[b] == 0:
                del cache_refs[b]
        elif op == "free" and live:
            rid = live.pop(rng.randint(len(live)))
            pager.free(rid)  # cache-shared pages must survive this
        elif op == "fork" and live:
            rid = live[rng.randint(len(live))]
            pos = rng.randint(pager.length(rid))
            try:
                copy = pager.ensure_writable(rid, pos)
            except PoolExhausted:
                assert pager.free_blocks == 0
                continue
            if copy is not None:
                src, dst = copy
                assert src != dst
                assert dst in pager.block_table(rid)
                assert src not in pager.block_table(rid)
        pager.check_invariants(extra_refs=cache_refs)

    for rid in live:
        pager.free(rid)
    for b, n in list(cache_refs.items()):
        for _ in range(n):
            pager.release(b)
    pager.check_invariants()
    assert pager.free_blocks == pager.num_blocks


def test_pager_cow_forks_only_shared_pages():
    """ensure_writable is a no-op on private pages, forks shared ones, and
    the fork leaves the original alive for its other reference."""
    pager = KVPager(num_blocks=8, block_size=4)
    t0 = pager.alloc(0, 8)  # two blocks
    assert pager.ensure_writable(0, 5) is None  # private: nothing to do
    pager.share(t0[1])  # emulate a prefix-cache ref on block 1
    src, dst = pager.ensure_writable(0, 5)
    assert src == t0[1] and dst != src
    assert pager.refcount(src) == 1 and pager.refcount(dst) == 1
    pager.check_invariants(extra_refs={src: 1})
    pager.free(0)
    pager.release(src)
    pager.check_invariants()
    assert pager.free_blocks == pager.num_blocks


def test_pager_prefix_alloc_shares_blocks():
    """alloc(prefix_blocks=...) increfs resident pages instead of popping
    fresh ones; freeing either owner keeps the other's view alive."""
    pager = KVPager(num_blocks=8, block_size=4)
    ta = pager.alloc(0, 12)  # 3 blocks
    popped = pager.blocks_allocated
    tb = pager.alloc(1, 12, prefix_blocks=ta[:2], prefix_len=8)
    assert pager.blocks_allocated == popped + 1  # only the suffix popped
    assert tb[:2] == ta[:2] and tb[2] != ta[2]
    assert pager.refcount(ta[0]) == 2
    pager.check_invariants()
    pager.free(0)
    assert pager.refcount(ta[0]) == 1  # request 1 still reads it
    pager.check_invariants()
    pager.free(1)
    pager.check_invariants()
    assert pager.free_blocks == pager.num_blocks
    with pytest.raises(ValueError):
        # a full-prompt prefix must still leave >= 1 token to prefill
        pager.alloc(2, 8, prefix_blocks=[1, 2], prefix_len=8)


def test_pager_padded_table_uses_garbage_page():
    pager = KVPager(num_blocks=8, block_size=4)
    pager.alloc(7, 10)
    t = pager.padded_table(7, 6)
    assert t.shape == (6,) and t.dtype == np.int32
    assert (t[3:] == 0).all() and (t[:3] > 0).all()


# --------------------------------------------------------------- scheduler


def _req(rid, prompt_len, max_new=4):
    return Request(rid=rid, prompt=list(range(1, prompt_len + 1)),
                   max_new_tokens=max_new)


def test_scheduler_admission_bounded_by_pool_and_round_width():
    pager = KVPager(num_blocks=4, block_size=4)
    sched = ContinuousBatchingScheduler(pager, max_in_flight=8)
    for rid in range(3):
        sched.submit(_req(rid, prompt_len=8))  # 2 blocks each
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0, 1]  # third doesn't fit
    assert sched.admit() == []
    sched.finish(admitted[0])
    assert [r.rid for r in sched.admit()] == [2]
    pager.check_invariants()


def test_scheduler_admission_is_fifo_under_pressure():
    """A big head request blocks smaller ones behind it (no starvation)."""
    pager = KVPager(num_blocks=4, block_size=4)
    sched = ContinuousBatchingScheduler(pager, max_in_flight=8)
    sched.submit(_req(0, prompt_len=8))
    sched.submit(_req(1, prompt_len=30))  # 8 blocks: never fits beside rid 0
    sched.submit(_req(2, prompt_len=4))
    assert [r.rid for r in sched.admit()] == [0]
    assert sched.admit() == []  # rid 2 must wait its turn behind rid 1


def test_scheduler_preempts_latest_admitted_on_growth():
    pager = KVPager(num_blocks=3, block_size=4)
    sched = ContinuousBatchingScheduler(pager, max_in_flight=4)
    a, b, c = _req(0, 4), _req(1, 4), _req(2, 4)
    for r in (a, b, c):
        sched.submit(r)
    admitted = sched.admit()
    assert len(admitted) == 3  # one block each, pool now full
    for r in admitted:
        sched.promote(r)  # prefill done; decode from here on
    # growing the oldest evicts the newest, never the oldest itself
    for _ in range(pager.block_size):
        sched.reserve_decode_slot(a)
    assert c.state is RequestState.WAITING and c.preemptions == 1
    assert a.state is RequestState.RUNNING
    assert sched.waiting[0] is c  # re-queued at the front
    pager.check_invariants()


def test_scheduler_lone_request_overflow_raises():
    pager = KVPager(num_blocks=1, block_size=2)
    sched = ContinuousBatchingScheduler(pager, max_in_flight=2)
    r = _req(0, prompt_len=2)
    sched.submit(r)
    sched.admit()
    with pytest.raises(PoolExhausted):
        sched.reserve_decode_slot(r)  # nothing else to evict


# ------------------------------------------------- paged attention parity


def _paged_problem(rng, lengths, *, h, kh, d, blk, extra_blocks=3):
    """Random pools + disjoint shuffled block tables for given ragged
    lengths. Returns (q, k_pool, v_pool, block_tables [B, M])."""
    lengths = np.asarray(lengths, np.int32)
    bsz = len(lengths)
    nb_per = [-(-int(n) // blk) for n in lengths]
    m = max(nb_per)
    total = sum(nb_per)
    nb = total + 1 + extra_blocks  # + garbage page 0
    q = jnp.asarray(rng.randn(bsz, h, d), jnp.float32)
    kp = jnp.asarray(rng.randn(nb, blk, kh, d), jnp.float32)
    vp = jnp.asarray(rng.randn(nb, blk, kh, d), jnp.float32)
    ids = rng.permutation(np.arange(1, nb))[:total]
    bt = np.zeros((bsz, m), np.int32)
    off = 0
    for r, n in enumerate(nb_per):
        bt[r, :n] = ids[off:off + n]
        off += n
    return q, kp, vp, jnp.asarray(bt)


def _dense_ref_rows(q, kp, vp, bt, lengths):
    """Row-by-row oracle via models.common.decode_attention (the dense
    public entry) over each request's gathered pages at its own position."""
    blk, kh, d = kp.shape[1], kp.shape[2], kp.shape[3]
    m = bt.shape[1]
    zeros = jnp.zeros((1, 1, kh, d), q.dtype)
    outs = []
    for r, n in enumerate(lengths):
        k = kp[bt[r]].reshape(1, m * blk, kh, d)
        v = vp[bt[r]].reshape(1, m * blk, kh, d)
        o, _, _ = mc.decode_attention(NULL_CTX, q[r:r + 1, None], k, v,
                                      zeros, zeros, int(n) - 1, update=False)
        outs.append(o[:, 0])
    return jnp.concatenate(outs, axis=0)


def test_paged_decode_matches_dense_on_ragged_lengths(rng):
    """One round, per-request lengths spanning 8x: kernel vs the dense
    models.common.decode_attention entry AND the kernel's own ref oracle."""
    lengths = [16, 40, 128]  # 8x spread within one round
    q, kp, vp, bt = _paged_problem(rng, lengths, h=8, kh=2, d=16, blk=16)
    lens = jnp.asarray(lengths, jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lens)
    ref = _dense_ref_rows(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    oracle = paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)


def test_paged_jnp_twin_matches_dense_on_ragged_lengths(rng):
    lengths = [4, 27, 64]
    q, kp, vp, bt = _paged_problem(rng, lengths, h=4, kh=4, d=8, blk=8)
    out = mc.paged_decode_attention(q[:, None], kp, vp, bt,
                                    jnp.asarray(lengths, jnp.int32))[:, 0]
    ref = _dense_ref_rows(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_explicit_depth_and_padding_rows(rng):
    """Depth sweep + a zero-length padding slot pointing at the garbage
    page: real rows stay exact, the padding row is finite garbage."""
    lengths = [32, 8, 0]
    q, kp, vp, bt = _paged_problem(rng, lengths, h=4, kh=2, d=16, blk=8)
    bt = bt.at[2].set(0)  # padding slot: all garbage page
    ref = _dense_ref_rows(q, kp, vp, bt, lengths[:2])
    for depth in (1, 2, 5):
        out = paged_decode_attention(q, kp, vp, bt,
                                     jnp.asarray(lengths, jnp.int32),
                                     depth=depth)
        np.testing.assert_allclose(np.asarray(out[:2]), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        assert np.isfinite(np.asarray(out)).all()


@pytest.mark.slow
@pytest.mark.parametrize("kh,h,blk", [(2, 8, 16), (1, 4, 32), (4, 4, 8)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_paged_decode_ragged_sweep(kh, h, blk, seed):
    """Long ragged-parity sweep: random length mixes with >=4x spread."""
    rng = np.random.RandomState(100 + seed)
    base = int(rng.randint(1, 2 * blk))
    lengths = sorted(rng.randint(base, 8 * base + 1, size=4).tolist())
    lengths[0], lengths[-1] = base, max(lengths[-1], 4 * base)  # >=4x spread
    q, kp, vp, bt = _paged_problem(rng, lengths, h=h, kh=kh, d=16, blk=blk)
    out = paged_decode_attention(q, kp, vp, bt, jnp.asarray(lengths, jnp.int32))
    ref = _dense_ref_rows(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ engine


def _f32_cfg():
    return get_config("yi-6b").reduced().replace(dtype="float32",
                                                 param_dtype="float32")


def test_engine_matches_dense_generation():
    """One request through the paged engine equals the dense prefill +
    decode_step loop token-for-token (float32)."""
    cfg = _f32_cfg()
    rng = np.random.default_rng(1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = rng.integers(0, cfg.vocab, 12)
    gen = 6

    cache, logits = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, pad_to=12 + gen)
    tok = int(jnp.argmax(logits[0, -1]))
    dense = [tok]
    for _ in range(gen - 1):
        logits, cache = model.decode_step(
            params, cache, {"tokens": jnp.asarray([[tok]], jnp.int32)})
        tok = int(jnp.argmax(logits[0, -1]))
        dense.append(tok)

    eng = PagedServingEngine(cfg, block_size=4, num_blocks=16, params=params)
    rid = eng.submit(prompt, max_new_tokens=gen)
    stats = eng.run()
    assert eng.request(rid).generated == dense
    assert stats["completed"] == 1


def test_engine_oversubscribes_dense_footprint():
    """A fixed pool serves aggregate KV >= 2x its own capacity (i.e. >= 2x
    any dense [batch, max_len] carve-up of the same memory): completions
    free pages that later admissions reuse."""
    cfg = _f32_cfg()
    rng = np.random.default_rng(2)
    blk, gen = 4, 5
    plens = [5, 17, 6, 15, 7, 13, 9, 16]
    blocks_per_req = -(-(max(plens) + gen) // blk)
    eng = PagedServingEngine(cfg, block_size=blk,
                             num_blocks=2 * blocks_per_req, max_in_flight=3)
    rids = [eng.submit(rng.integers(0, cfg.vocab, n), max_new_tokens=gen)
            for n in plens]
    stats = eng.run()  # run() checks pager invariants at drain
    assert stats["completed"] == len(plens)
    assert stats["aggregate_kv_tokens"] >= 2 * stats["pool_tokens"]
    for rid in rids:
        assert len(eng.request(rid).generated) == gen


def test_engine_preemption_under_pool_pressure():
    """A pool barely bigger than one request forces preemption; the evicted
    request still finishes with the full token count."""
    cfg = _f32_cfg()
    rng = np.random.default_rng(3)
    blk, gen = 4, 6
    plens = [10, 10, 10]
    blocks_per_req = -(-(max(plens) + gen) // blk)
    eng = PagedServingEngine(cfg, block_size=blk,
                             num_blocks=blocks_per_req + 2, max_in_flight=3)
    rids = [eng.submit(rng.integers(0, cfg.vocab, n), max_new_tokens=gen)
            for n in plens]
    stats = eng.run()
    assert stats["preemptions"] > 0
    assert stats["completed"] == len(plens)
    for rid in rids:
        assert len(eng.request(rid).generated) == gen


def test_engine_rejects_unservable_shapes():
    cfg = _f32_cfg()
    eng = PagedServingEngine(cfg, block_size=4, num_blocks=4)
    with pytest.raises(ValueError):
        eng.submit(np.arange(1, 9), max_new_tokens=64)  # 18 blocks > pool
    with pytest.raises(ValueError):
        eng.submit([], max_new_tokens=1)
    with pytest.raises(ValueError):
        PagedServingEngine(get_config("mamba2-130m").reduced(),
                           block_size=4, num_blocks=4)

#!/usr/bin/env bash
# One command to check the suite's green state.
#
#   scripts/ci.sh        -> lint, fast lane (-m "not slow"), then tier-1
#   scripts/ci.sh fast   -> lint + fast lane only
#
# The tier-1 command (ROADMAP.md): PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Lint first (config in pyproject.toml [tool.ruff]). The container image
# does not bake ruff in, so skip with a notice when it is unavailable
# rather than failing the whole lane.
echo "== lint: ruff check =="
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks examples scripts
elif python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check src tests benchmarks examples scripts
else
    echo "ruff not installed; skipping lint (pip install ruff to enable)"
fi

echo "== fast lane: python -m pytest -q -m 'not slow' =="
python -m pytest -q -m "not slow"

echo "== paged-serving smoke: examples/serve_batched.py --engine paged =="
echo "   (includes the prefix smoke: shared system prompt must hit the"
echo "    prefix cache and pop strictly fewer pool blocks than cache-off)"
python examples/serve_batched.py --engine paged --prefix-cache

echo "== machine smoke: far-memory profile must solve strictly deeper =="
near_json="$(python scripts/machine_smoke.py)"
far_json="$(REPRO_MACHINE=v5e-far-800ns python scripts/machine_smoke.py)"
echo "$near_json"
echo "$far_json"
python - "$near_json" "$far_json" <<'EOF'
import json, sys
near, far = (json.loads(a) for a in sys.argv[1:3])
assert near["machine"] == "v5e" and far["machine"] == "v5e-far-800ns", (near, far)
assert far["solved_depth"] > near["solved_depth"], (
    f"v5e-far-800ns depth {far['solved_depth']} must exceed "
    f"v5e depth {near['solved_depth']}")
print(f"ok: depth {near['solved_depth']} (v5e) -> "
      f"{far['solved_depth']} (v5e-far-800ns)")
EOF

if [[ "${1:-}" == "fast" ]]; then
    exit 0
fi

echo "== tier-1: python -m pytest -x -q =="
python -m pytest -x -q

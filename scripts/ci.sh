#!/usr/bin/env bash
# One command to check the suite's green state.
#
#   scripts/ci.sh        -> lint, fast lane (-m "not slow"), then tier-1
#   scripts/ci.sh fast   -> lint + fast lane only
#
# The tier-1 command (ROADMAP.md): PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Lint first (config in pyproject.toml [tool.ruff]). The container image
# does not bake ruff in, so skip with a notice when it is unavailable
# rather than failing the whole lane.
echo "== lint: ruff check =="
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks examples scripts
elif python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check src tests benchmarks examples scripts
else
    echo "ruff not installed; skipping lint (pip install ruff to enable)"
fi

echo "== fast lane: python -m pytest -q -m 'not slow' =="
python -m pytest -q -m "not slow"

echo "== paged-serving smoke: examples/serve_batched.py --engine paged =="
python examples/serve_batched.py --engine paged

if [[ "${1:-}" == "fast" ]]; then
    exit 0
fi

echo "== tier-1: python -m pytest -x -q =="
python -m pytest -x -q

#!/usr/bin/env bash
# One command to check the suite's green state.
#
#   scripts/ci.sh        -> fast lane (-m "not slow") then the tier-1 command
#   scripts/ci.sh fast   -> fast lane only
#
# The tier-1 command (ROADMAP.md): PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== fast lane: python -m pytest -q -m 'not slow' =="
python -m pytest -q -m "not slow"

if [[ "${1:-}" == "fast" ]]; then
    exit 0
fi

echo "== tier-1: python -m pytest -x -q =="
python -m pytest -x -q

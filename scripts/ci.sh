#!/usr/bin/env bash
# One command to check the suite's green state.
#
#   scripts/ci.sh        -> lint, fast lane (-m "not slow"), then tier-1
#   scripts/ci.sh fast   -> lint + fast lane only
#
# The tier-1 command (ROADMAP.md): PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Lint first (config in pyproject.toml [tool.ruff]). The container image
# does not bake ruff in, so skip with a notice when it is unavailable
# rather than failing the whole lane.
echo "== lint: ruff check =="
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks examples scripts
elif python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check src tests benchmarks examples scripts
else
    echo "ruff not installed; skipping lint (pip install ruff to enable)"
fi

echo "== fast lane: python -m pytest -q -m 'not slow' =="
python -m pytest -q -m "not slow"

echo "== paged-serving smoke: examples/serve_batched.py --engine paged =="
echo "   (includes the prefix smoke: shared system prompt must hit the"
echo "    prefix cache and pop strictly fewer pool blocks than cache-off)"
python examples/serve_batched.py --engine paged --prefix-cache

echo "== trace smoke: paged serve with --trace must emit a valid Perfetto trace =="
trace_out="$(mktemp /tmp/repro_trace.XXXXXX.json)"
python -m repro.launch.serve --arch yi-6b --reduced --batch 2 \
    --prompt-len 16 --gen 3 --engine paged --block-size 4 \
    --trace "$trace_out" >/dev/null
python - "$trace_out" <<'EOF'
import json, sys
path = sys.argv[1]
doc = json.load(open(path))  # raises on missing/invalid JSON
events = doc["traceEvents"]
assert events, f"{path}: traceEvents is empty"
names = {ev["name"] for ev in events}
for required in ("round", "decode_round", "pipeline:paged_decode"):
    assert required in names, f"{path}: missing '{required}' spans ({sorted(names)})"
print(f"ok: {len(events)} trace events ({len(names)} span kinds) in {path}")
EOF
rm -f "$trace_out"

echo "== metrics smoke: kernel_bench --json must embed the registry snapshot =="
bench_out="$(mktemp /tmp/repro_bench.XXXXXX.json)"
PYTHONPATH="$PYTHONPATH:." python -m benchmarks.kernel_bench --json > "$bench_out"
python - "$bench_out" <<'EOF'
import json, sys
rep = json.load(open(sys.argv[1]))
assert "metrics" in rep and "autotune" in rep["metrics"], rep.keys()
kernels = rep["metrics"]["autotune"]["kernels"]
assert kernels, "kernel_bench run recorded no autotune samples"
with_bd = [k for k, v in rep["kernels"].items() if v.get("breakdown")]
assert with_bd, "no kernel produced a stall breakdown"
print(f"ok: metrics snapshot covers {len(kernels)} kernels; "
      f"breakdown on {sorted(with_bd)}")
EOF
rm -f "$bench_out"

echo "== chaos smoke: seeded fault schedule, every request must go terminal =="
python scripts/chaos_serve.py --seed 0 --rounds 50

echo "== guard smoke: kernel-site chaos under parity sentinels (ISSUE-10) =="
guard_out="$(mktemp /tmp/repro_guard.XXXXXX.json)"
REPRO_PARITY=sampled python scripts/chaos_serve.py --seed 3 --rounds 40 > "$guard_out"
python - "$guard_out" <<'EOF'
import json, sys
s = json.load(open(sys.argv[1]))
sub = s["substrate"]
by_site = s["faults"]["by_site"]
hits = sum(by_site.get(k, 0)
           for k in ("kernel_compile", "kernel_oom", "kernel_nan"))
assert hits > 0, f"chaos never hit a kernel site (pick a new seed): {by_site}"
assert sub["parity_mismatches"] == 0, sub
assert sub["injected_faults"] > 0, sub
print(f"ok: {hits} kernel-site faults absorbed, "
      f"{sub['parity_checks']} parity checks, 0 mismatches")
EOF
rm -f "$guard_out"

echo "== strict smoke: clean kernel bench under --strict must never degrade =="
strict_out="$(mktemp /tmp/repro_strict.XXXXXX.json)"
PYTHONPATH="$PYTHONPATH:." REPRO_PARITY=full \
    python -m benchmarks.kernel_bench --json --strict > "$strict_out"
python - "$strict_out" <<'EOF'
import json, sys
sub = json.load(open(sys.argv[1]))["substrate"]
assert sub["strict"], sub
assert sub["guarded_calls"] > 0, "bench made no guarded coro_calls"
assert sub["guarded_calls"] == sub["clean_calls"], sub
for k in ("backoffs", "fallbacks", "parity_mismatches", "breaker_trips"):
    assert sub[k] == 0, f"clean strict run degraded: {k}={sub[k]} ({sub})"
print(f"ok: {sub['guarded_calls']} guarded calls, all clean, "
      f"{sub['parity_checks']} full-parity checks under --strict")
EOF
rm -f "$strict_out"

echo "== machine smoke: far-memory profile must solve strictly deeper =="
near_json="$(python scripts/machine_smoke.py)"
far_json="$(REPRO_MACHINE=v5e-far-800ns python scripts/machine_smoke.py)"
echo "$near_json"
echo "$far_json"
python - "$near_json" "$far_json" <<'EOF'
import json, sys
near, far = (json.loads(a) for a in sys.argv[1:3])
assert near["machine"] == "v5e" and far["machine"] == "v5e-far-800ns", (near, far)
assert far["solved_depth"] > near["solved_depth"], (
    f"v5e-far-800ns depth {far['solved_depth']} must exceed "
    f"v5e depth {near['solved_depth']}")
print(f"ok: depth {near['solved_depth']} (v5e) -> "
      f"{far['solved_depth']} (v5e-far-800ns)")
EOF

if [[ "${1:-}" == "fast" ]]; then
    exit 0
fi

echo "== tier-1: python -m pytest -x -q =="
python -m pytest -x -q

"""Re-measure decode/long cells with exact full-depth unrolled compiles."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import ALL_ARCH_NAMES, SHAPES, cell_supported, get_config
from repro.launch.dryrun import run_cell


def main():
    for arch in ALL_ARCH_NAMES:
        for shape in ("decode_32k", "long_500k"):
            if not cell_supported(get_config(arch), SHAPES[shape])[0]:
                continue
            try:
                rec = run_cell(arch, shape, "single", out_dir="reports/dryrun",
                               verbose=False, full_unroll=True)
                print(arch, shape, "ok", f"{rec['hlo_flops_per_chip']:.3e}",
                      rec["dominant"], flush=True)
            except Exception as e:  # keep sweeping
                print(arch, shape, "ERROR", repr(e), flush=True)
    print("DONE", flush=True)


if __name__ == "__main__":
    main()

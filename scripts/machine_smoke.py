"""CI smoke: the machine profile must reach the depth solver end-to-end.

Runs one real kernel (coro_gather, interpret mode) under the ACTIVE machine
profile (`REPRO_MACHINE`) and prints a one-line JSON record with the
unclamped solved depth for the row-gather spec, the depth the launched
pipeline actually ran (clamped to its tile count), and the telemetry state.
`scripts/ci.sh` runs this twice — default profile and `v5e-far-800ns` — and
asserts the far solve is strictly deeper (the paper's latency dial, wired
through the env var).

  REPRO_MACHINE=v5e-far-800ns PYTHONPATH=src python scripts/machine_smoke.py
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.core.machine import get_machine
from repro.kernels.coro_gather.coro_gather import row_gather_spec
from repro.kernels.coro_gather.ops import coro_gather
from repro.kernels.coro_gather.ref import gather_ref


def main():
    m = get_machine()
    spec = row_gather_spec(8, 128, jnp.float32)
    solved = autotune.choose_depth(spec.profile(), vars=spec.all_vars())

    rng = np.random.RandomState(0)
    table = jnp.asarray(rng.randn(64, 128), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, 32), jnp.int32)
    out = coro_gather(table, idx, interpret=True)
    assert out.shape == (32, 128)
    assert bool(jnp.allclose(out, gather_ref(table, idx)))

    print(json.dumps({
        "machine": m.name,
        "hbm_latency_ns": round(m.hbm_latency_s * 1e9, 1),
        "solved_depth": solved,
        "ran_depth": autotune.last_choice("row_gather"),
    }))


if __name__ == "__main__":
    main()

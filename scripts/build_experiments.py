"""Regenerate EXPERIMENTS.md §Dry-run and §Roofline tables from reports/dryrun."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import ALL_ARCH_NAMES, ALL_SHAPE_NAMES  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
RPT = ROOT / "reports" / "dryrun"


def load():
    recs = {}
    for p in RPT.glob("*.json"):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_e(x):
    return f"{x:.2e}"


def dryrun_table(recs):
    lines = [
        "| arch | shape | single 16×16 | multi 2×16×16 | args GiB/dev | temp GiB/dev | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ALL_ARCH_NAMES:
        for s in ALL_SHAPE_NAMES:
            r1 = recs.get((a, s, "single"))
            r2 = recs.get((a, s, "multi"))
            if r1 is None:
                continue
            if r1["status"] == "skipped":
                lines.append(f"| {a} | {s} | SKIP | SKIP | — | — | — |"
                             f" <!-- {r1['reason'][:60]} -->")
                continue
            st1 = "✅ ok" if r1["status"] == "ok" else "❌"
            st2 = "✅ ok" if (r2 or {}).get("status") == "ok" else "❌"
            mem = r1.get("memory", {})
            lines.append(
                f"| {a} | {s} | {st1} | {st2} | "
                f"{mem.get('argument_size_in_bytes', 0)/2**30:.2f} | "
                f"{mem.get('temp_size_in_bytes', 0)/2**30:.2f} | "
                f"{r1.get('compile_s', 0)} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful FLOPs ratio |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ALL_ARCH_NAMES:
        for s in ALL_SHAPE_NAMES:
            r = recs.get((a, s, "single"))
            if r is None or r["status"] != "ok":
                if r is not None and r["status"] == "skipped":
                    lines.append(f"| {a} | {s} | — | — | — | skipped | — |")
                continue
            t = r["terms"]
            lines.append(
                f"| {a} | {s} | {fmt_e(t['compute_s'])} | {fmt_e(t['memory_s'])} | "
                f"{fmt_e(t['collective_s'])} | **{r['dominant'].replace('_s','')}** | "
                f"{r['useful_flops_ratio']:.3f} |")
    return "\n".join(lines)


def replace_section(text, marker, body):
    """Idempotent: replaces marker..<!-- END --> with fresh content."""
    assert marker in text, marker
    i = text.index(marker)
    end = "<!-- END -->"
    j = text.index(end, i) + len(end)
    return text[:i] + marker + "\n\n" + body + "\n" + end + text[j:]


def main():
    recs = load()
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    # strip anything previously inserted after the markers up to next header
    exp = replace_section(exp, "<!-- DRYRUN_TABLE -->", dryrun_table(recs))
    exp = replace_section(exp, "<!-- ROOFLINE_TABLE -->", roofline_table(recs))
    (ROOT / "EXPERIMENTS.md").write_text(exp)
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    print(f"tables written: {n_ok} ok cells, {n_skip} skips")


if __name__ == "__main__":
    main()

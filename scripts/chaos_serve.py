#!/usr/bin/env python
"""Chaos harness: seeded fault schedules against the paged serving engine.

Drives a randomized workload (ragged prompts, shared system-prompt
prefixes with mid-block divergence, staggered arrivals, tight pool) under
a deterministic `serve.FaultInjector` schedule — pool exhaustion, reclaim
refusal, preemption refusal, injected decode/prefill exceptions, latency
spikes, and the ISSUE-10 kernel-substrate sites (compile failure, VMEM
exhaustion, NaN poisoning, handled by `core.guard`'s backoff ladder and
twin fallback) — and asserts after EVERY round that

  * `KVPager.check_invariants` holds (free xor refcounted, exact
    refcounts, no garbage-page allocation), and
  * no exception escapes the engine round loop.

At drain it asserts every submitted request landed in a terminal state
(FINISHED / CANCELLED / FAILED) — the ISSUE-9 guarantee: the former
pool-pressure crash class is now a tested property — and that the parity
sentinel (forced on, `REPRO_PARITY=sampled`) recorded ZERO kernel/twin
mismatches: kernel faults may degrade throughput, never answers
(ISSUE-10). Exits non-zero (an AssertionError) on any violation; prints a
JSON summary (including `core.guard` substrate stats) on success.

  PYTHONPATH=src python scripts/chaos_serve.py --seed 0 --rounds 50
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# chaos runs police kernel/twin parity: must be set before repro imports so
# core.guard resolves the mode at module init
os.environ.setdefault("REPRO_PARITY", "sampled")

import numpy as np  # noqa: E402

from repro.core import guard  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.serve import (  # noqa: E402
    FaultInjector,
    PagedServingEngine,
    TERMINAL_STATES,
)


def build_engine(args) -> PagedServingEngine:
    cfg = get_config(args.arch).reduced().replace(dtype="float32",
                                                  param_dtype="float32")
    faults = FaultInjector(args.seed, latency_spike_s=args.spike_s)
    return PagedServingEngine(
        cfg, block_size=args.block_size, num_blocks=args.num_blocks,
        prefill_chunk=args.prefill_chunk, seed=args.seed, faults=faults,
        deadline_s=args.deadline_s, max_queue=args.max_queue)


def workload(args, rng, vocab):
    """(arrival_round, prompt, max_new) triples: half the prompts open with
    a shared system prefix whose tail diverges mid-block (the reproduced
    ISSUE-9 crash shape), arrivals staggered across the first rounds."""
    system = rng.integers(0, vocab, args.block_size + args.block_size // 2)
    jobs = []
    for i in range(args.requests):
        plen = int(rng.integers(2, args.prompt_len + 1))
        prompt = rng.integers(0, vocab, plen)
        if i % 2 == 0:
            n = min(len(system), plen - 1)  # leave >=1 token to prefill
            prompt[:n] = system[:n]
        gen = int(rng.integers(1, args.gen + 1))
        arrival = int(rng.integers(0, max(args.rounds // 2, 1)))
        jobs.append((arrival, prompt, gen))
    return sorted(jobs, key=lambda j: j[0])


def check_round(eng) -> None:
    eng.pager.check_invariants(
        eng.prefix_cache.block_refs() if eng.prefix_cache else None)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=50,
                    help="chaos rounds to drive (then drain to terminal)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--block-size", type=int, default=4)
    ap.add_argument("--num-blocks", type=int, default=10,
                    help="tight on purpose: pressure is the point")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=5)
    ap.add_argument("--prefill-chunk", type=int, default=4)
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--max-queue", type=int, default=None)
    ap.add_argument("--spike-s", type=float, default=1e-3)
    args = ap.parse_args(argv)

    eng = build_engine(args)
    rng = np.random.default_rng(args.seed)
    jobs = workload(args, rng, eng.cfg.vocab)
    rids = []

    for r in range(args.rounds):
        while jobs and jobs[0][0] <= r:
            _, prompt, gen = jobs.pop(0)
            rids.append(eng.submit(prompt, max_new_tokens=gen))
        eng.step_round()
        check_round(eng)
        if not jobs and not eng.scheduler.has_work():
            break
    # late arrivals that never got their round
    for _, prompt, gen in jobs:
        rids.append(eng.submit(prompt, max_new_tokens=gen))

    stats = eng.run()  # drains; never raises on a wedged workload
    check_round(eng)

    non_terminal = [rid for rid in rids
                    if eng.request(rid).state not in TERMINAL_STATES]
    assert not non_terminal, f"requests not terminal: {non_terminal}"
    assert stats["requests"] == len(rids)
    accounted = (stats["completed"] + stats["cancelled"] + stats["failed"])
    assert accounted == len(rids), (accounted, len(rids), stats)

    # the ISSUE-10 guarantee: whatever the kernel sites injected, every
    # answer the substrate produced agrees with its jnp twin
    substrate = guard.stats()
    assert substrate["parity_mismatches"] == 0, substrate

    summary = {
        "seed": args.seed,
        "rounds": stats["rounds"],
        "requests": len(rids),
        "completed": stats["completed"],
        "cancelled": stats["cancelled"],
        "failed": stats["failed"],
        "shed": stats["shed"],
        "deadline_expired": stats["deadline_expired"],
        "stalled": stats["stalled"],
        "stalls": stats["stalls"],
        "step_faults": stats["step_faults"],
        "preemptions": stats["preemptions"],
        "faults": eng.faults.stats(),
        "substrate": substrate,
    }
    print(json.dumps(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
